//! Plane-agnostic scheduling core: ONE placement policy for all three
//! execution planes.
//!
//! Before this module existed the repo had three divergent placement
//! implementations — the closed-loop scheduler, the open-loop DES and
//! the wallclock server each re-implemented routing (the server by
//! string-matching strategy names, silently falling back to
//! latency-aware on a typo). [`PlacementPolicy`] now owns the full
//! placement decision and every plane drives it:
//!
//! - **routing** — strategy resolution goes through
//!   [`router::build`], so an unknown name fails loudly and identically
//!   in `run`, `serve` and `bench`; whole-corpus placement uses
//!   [`Strategy::assign`], on-arrival placement uses
//!   [`Strategy::route_one`] with live backlog;
//! - **SLO deferral** — [`PlacementPolicy::plan_release`] picks the
//!   cleanest forecast window inside a `Deferrable` prompt's deadline
//!   slack (the temporal-shifting planner, shared verbatim by the DES,
//!   the wallclock ingest and the closed-loop corpus plan);
//! - **batch formation** — [`PlacementPolicy::plan_corpus`] orders each
//!   device queue by release time (SLO-aware ordering) and forms
//!   admission-controlled batches;
//! - **carbon-aware batch sizing** —
//!   [`PlacementPolicy::plan_batch_hold`]: a free device holding only a
//!   *partial* batch of `Deferrable` prompts may wait for a forecast
//!   clean window instead of launching immediately. Interactive traffic
//!   always pre-empts a hold, and the hold is bounded by every member's
//!   deadline minus a service-time safety margin;
//! - **receding-horizon re-planning** — with the `replan` knob on, a
//!   [`crate::grid::DriftTracker`] scores the active plan's forecast
//!   against realized trace samples online; when drift trips (or on the
//!   fixed replan cadence) every plane re-plans its *held* work through
//!   [`PlacementPolicy::replan_release`] /
//!   [`PlacementPolicy::replan_batch_hold`]: a drift trigger releases
//!   early (the promised window can no longer be trusted), a cadence
//!   trigger re-runs the planner against the fresh fit (the hold may
//!   move earlier or later, never past the SLO deadline bound). With
//!   `replan` off — the default — decisions are bit-for-bit identical
//!   to plan-once, pinned by `tests/planes.rs`.
//!
//! ## Equivalence guarantee
//!
//! Under the default configuration (no grid context, every prompt
//! `Interactive`) the policy core reproduces the pre-refactor pipeline
//! decision-for-decision: `plan_corpus` sorts by release time, which is
//! arrival order, so the batch plan equals
//! `form_batches(strategy.assign(..))` exactly — pinned by the
//! cross-plane equivalence test in `tests/planes.rs`.

use std::sync::Arc;

use anyhow::Result;

use crate::cluster::{CarbonModel, Cluster, HealthMask};
use crate::grid::{shift, DriftTracker, ForecastCache, ForecastKind, GridTrace, ReplanTrigger};
use crate::telemetry::trace::{TraceEvent, TraceSink};
use crate::util::sync::Snapshot;
use crate::workload::Prompt;

use super::batcher::{form_batches_ordered, Batch, Grouping};
use super::estimator::{BenchmarkDb, DeviceId};
use super::router::{self, OnlineView, RouteContext, Strategy};

/// Shape of the drift-blend weight as a function of the rolling
/// one-step-ahead MAPE (see [`GridShiftConfig::forecast_at`]). All
/// curves agree at the endpoints — weight 0 at zero error, full
/// persistence (weight 1) at `drift_threshold` — and differ in how
/// aggressively they discount in between, over the normalized error
/// `r = clamp(mape / drift_threshold, 0, 1)`:
///
/// - [`Linear`](Self::Linear): `w = r` — PR-5's original curve;
/// - [`ClampedQuadratic`](Self::ClampedQuadratic): `w = r²` — gentle
///   on benign noise (small MAPE barely discounts the fit, keeping
///   clean-window planning sharp), still saturating on true drift.
///   The default: on the drift-injected `bench shifting` scenario it
///   holds the linear curve's carbon under drift without giving up
///   savings while the forecaster is trustworthy (`blend_curve`
///   table);
/// - [`Step`](Self::Step): `w = [mape ≥ threshold]` — the binary
///   trust/distrust baseline (the replan trigger's shape, expressed
///   as a blend).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BlendCurve {
    Linear,
    #[default]
    ClampedQuadratic,
    Step,
}

impl BlendCurve {
    /// Every curve, in sweep/report order.
    pub const ALL: [BlendCurve; 3] =
        [BlendCurve::Linear, BlendCurve::ClampedQuadratic, BlendCurve::Step];

    /// Stable snake_case name for reports and bench tables.
    pub fn name(self) -> &'static str {
        match self {
            BlendCurve::Linear => "linear",
            BlendCurve::ClampedQuadratic => "clamped_quadratic",
            BlendCurve::Step => "step",
        }
    }

    /// The blend weight in `[0, 1]` for a rolling `mape` against
    /// `threshold` (positive finite, enforced where configured).
    pub fn weight(self, mape: f64, threshold: f64) -> f64 {
        let r = (mape / threshold).clamp(0.0, 1.0);
        match self {
            BlendCurve::Linear => r,
            BlendCurve::ClampedQuadratic => r * r,
            BlendCurve::Step => {
                if mape >= threshold {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }
}

/// Grid context for temporal shifting, forecast-aware routing, and
/// carbon-aware batch sizing. Shared by every plane.
#[derive(Debug, Clone)]
pub struct GridShiftConfig {
    /// Ground-truth intensity signal. Pair it with
    /// `CarbonModel::Trace` of the same trace on the cluster so
    /// planning and carbon accounting agree.
    pub trace: GridTrace,
    pub forecaster: ForecastKind,
    /// History steps the forecaster sees at each decision (≥ one day
    /// keeps seasonal models useful from t = 0; operators have
    /// yesterday's grid data).
    pub lookback_steps: usize,
    /// Planning horizon cap, steps.
    pub horizon_steps: usize,
    /// Hold `Deferrable` prompts for forecast low-carbon windows.
    pub defer: bool,
    /// Carbon-aware batch *sizing*: a free device holding only a
    /// partial batch of `Deferrable` prompts may wait for a forecast
    /// clean window instead of launching immediately.
    pub sizing: bool,
    /// Memoize the forecaster fit per trace step (the hot-path cache).
    /// `false` restores the refit-every-decision path — kept only for
    /// the equivalence tests and the `bench scale` cached-vs-uncached
    /// rows; decisions are bit-for-bit identical either way.
    pub memoize: bool,
    /// Receding-horizon re-planning of held work. Off (the default)
    /// keeps every plane's decisions bit-for-bit identical to
    /// plan-once; on, held prompts and sizing-held partial batches are
    /// re-planned whenever [`Self::replan_due`] fires.
    pub replan: bool,
    /// Drift-aware forecast *blending*: discount the fitted forecast
    /// toward persistence proportionally to the rolling
    /// realized-vs-forecast MAPE (full persistence once the MAPE
    /// reaches `drift_threshold`) — the continuous alternative to the
    /// binary trust/distrust replan trigger. Off (the default) keeps
    /// [`Self::forecast_at`] bit-for-bit the pure fit.
    pub blend: bool,
    /// Fixed replan cadence, seconds (defaults to one trace step).
    pub replan_interval_s: f64,
    /// Rolling-MAPE threshold that declares the active forecast wrong
    /// (fraction, e.g. 0.2 = 20 %).
    pub drift_threshold: f64,
    /// Rolling error window, trace steps.
    pub drift_window: usize,
    /// Blend weight as a function of the rolling MAPE (only consulted
    /// with `blend` on). Default [`BlendCurve::ClampedQuadratic`] —
    /// see the `blend_curve` sweep in `bench shifting`.
    pub blend_curve: BlendCurve,
    /// The per-step fit memo — a pure accelerator that never
    /// participates in a config's identity. Clones *share* the
    /// published fit (lock-free snapshot), so per-thread config clones
    /// start warm; sharing a deterministic memo cannot change a
    /// decision.
    cache: ForecastCache,
    /// Replan bookkeeping (anchored forecast + drift monitor + cadence
    /// clock); unlike the cache this is stateful, so clones start cold.
    drift: DriftTracker,
    /// Blending's own drift state (one-step-ahead rolling MAPE),
    /// deliberately separate from `drift`: sharing a tracker would let
    /// blending consume the per-step observations the replan trigger
    /// needs. Clones start cold.
    blend_drift: DriftTracker,
    /// Per-step memo of the *blended* forecast (the blend weight and
    /// the fit are constant within a step), keeping the per-decision
    /// path allocation-free with blending on. Like `cache`, clones
    /// share the published snapshot.
    blend_cache: BlendCache,
}

impl GridShiftConfig {
    /// Defaults: two days of lookback, two days of horizon, deferral
    /// on, sizing off, re-planning off (plan-once, the PR-3 baseline).
    pub fn new(trace: GridTrace, forecaster: ForecastKind) -> Self {
        let day = trace.steps_per_day();
        let step_s = trace.step_s;
        GridShiftConfig {
            trace,
            forecaster,
            lookback_steps: 2 * day,
            horizon_steps: 2 * day,
            defer: true,
            sizing: false,
            memoize: true,
            replan: false,
            blend: false,
            replan_interval_s: step_s,
            drift_threshold: 0.2,
            drift_window: 8,
            blend_curve: BlendCurve::default(),
            cache: ForecastCache::new(),
            drift: DriftTracker::new(),
            blend_drift: DriftTracker::new(),
            blend_cache: BlendCache::default(),
        }
    }

    /// Build from the cluster's carbon model when it is time-varying;
    /// `None` under a constant model (there is nothing to shift
    /// against, so every plane degrades to purely spatial placement).
    pub fn from_model(carbon: &CarbonModel, forecaster: ForecastKind, step_s: f64) -> Option<Self> {
        let trace = carbon.to_trace(step_s);
        if trace.len() <= 1 {
            return None;
        }
        Some(Self::new(trace, forecaster))
    }

    pub fn with_defer(mut self, defer: bool) -> Self {
        self.defer = defer;
        self
    }

    pub fn with_sizing(mut self, sizing: bool) -> Self {
        self.sizing = sizing;
        self
    }

    pub fn with_memoize(mut self, memoize: bool) -> Self {
        self.memoize = memoize;
        self
    }

    pub fn with_replan(mut self, replan: bool) -> Self {
        self.replan = replan;
        self
    }

    pub fn with_blend(mut self, blend: bool) -> Self {
        self.blend = blend;
        self
    }

    /// Pick the blend-weight curve (see [`BlendCurve`]; only consulted
    /// with `blend` on).
    pub fn with_blend_curve(mut self, curve: BlendCurve) -> Self {
        self.blend_curve = curve;
        self
    }

    /// Panics on a non-positive or non-finite interval — an infinite
    /// interval would otherwise panic much later inside the DES event
    /// queue (tick times must be finite); use a large finite value to
    /// effectively disable the cadence.
    pub fn with_replan_interval_s(mut self, interval_s: f64) -> Self {
        assert!(
            interval_s > 0.0 && interval_s.is_finite(),
            "replan interval must be positive and finite"
        );
        self.replan_interval_s = interval_s;
        self
    }

    /// Panics on a non-positive or non-finite threshold (the same
    /// contract `DriftMonitor::new` enforces — failing here beats
    /// failing at the first replan poll deep in the event loop).
    pub fn with_drift_threshold(mut self, threshold: f64) -> Self {
        assert!(
            threshold > 0.0 && threshold.is_finite(),
            "drift threshold must be positive and finite"
        );
        self.drift_threshold = threshold;
        self
    }

    /// Panics on a zero window (same contract as `DriftMonitor::new`).
    pub fn with_drift_window(mut self, window: usize) -> Self {
        assert!(window >= 1, "drift window must be >= 1 step");
        self.drift_window = window;
        self
    }

    /// Advance the drift tracker to `now` and decide whether a replan
    /// pass is due. `None` always when `replan` is off (one branch, no
    /// lock — the hot path pays nothing for the feature); otherwise a
    /// [`ReplanTrigger`] at most once per trace step for drift and once
    /// per `replan_interval_s` for cadence. Re-anchoring uses the
    /// memoized per-step fit, so a replan pass costs one fit.
    pub fn replan_due(&self, now: f64) -> Option<ReplanTrigger> {
        if !self.replan {
            return None;
        }
        // the drift monitor judges the RAW fit, never the blended one:
        // anchoring on the blend would let a saturated blend (already
        // near-persistence, so near-zero one-step error) mask exactly
        // the forecaster failure the Drift trigger exists to catch
        self.drift.check(
            &self.trace,
            self.drift_window,
            self.drift_threshold,
            self.replan_interval_s,
            now,
            |step| self.fit_at(step, self.horizon_steps.max(1)).1,
        )
    }

    /// Rolling realized-vs-forecast MAPE of the active plan (0 until
    /// the tracker has observed a step).
    pub fn drift_mape(&self) -> f64 {
        self.drift.mape()
    }

    /// The blend weight the next [`Self::forecast_at`] call at the
    /// current step would apply: [`BlendCurve::weight`] over the
    /// blending tracker's rolling one-step MAPE against
    /// `drift_threshold`, 0 with blending off. Read-only — the flight
    /// recorder stamps deferral events with it without advancing the
    /// tracker (and the MAPE read is lock-free).
    pub fn blend_weight(&self) -> f64 {
        if !self.blend {
            return 0.0;
        }
        self.blend_curve.weight(self.blend_drift.mape(), self.drift_threshold)
    }

    /// The fitted forecast at trace step `step_now`, long enough to
    /// index `horizon` steps ahead: `(current, forecast)` where
    /// `current` is the observed sample at `step_now` (history ends at
    /// `step_now` inclusive) and `forecast[j]` predicts step
    /// `step_now + 1 + j`.
    ///
    /// With `memoize` the forecaster is fitted once per trace step, to
    /// the full planning horizon, and later (shorter) requests at the
    /// same step are served as prefixes of that one fit — bit-for-bit
    /// what refitting at the shorter horizon returns, by the
    /// [`crate::grid::Forecaster`] prefix-consistency contract. Without `memoize`
    /// this refits at exactly `horizon` on every call (the pre-cache
    /// hot path, kept for equivalence tests and `bench scale`).
    ///
    /// With `blend` on (default off — bit-for-bit the pure fit), the
    /// fit is additionally discounted toward persistence by the
    /// rolling one-step-ahead MAPE: `blended[j] = (1−w)·fit[j] +
    /// w·current` with `w = blend_curve.weight(mape, drift_threshold)`
    /// (see [`BlendCurve`]). A trustworthy forecaster (MAPE ≈ 0) plans
    /// on its full fit; one that has been empirically wrong lately
    /// degrades smoothly into "assume the grid stays where it is" —
    /// the continuous version of the replan trigger's binary distrust.
    /// `w` only changes when the trace step advances, so blending
    /// preserves the forecaster prefix-consistency contract the memo
    /// relies on.
    pub fn forecast_at(&self, step_now: i64, horizon: usize) -> (f64, Arc<Vec<f64>>) {
        let (current, fit) = self.fit_at(step_now, horizon);
        if !self.blend {
            return (current, fit);
        }
        let mape = self.blend_drift.observe_to(
            &self.trace,
            self.drift_window,
            self.drift_threshold,
            step_now,
            |step| self.fit_at(step, self.horizon_steps.max(1)).1,
        );
        let w = self.blend_curve.weight(mape, self.drift_threshold);
        if w <= 0.0 {
            return (current, fit);
        }
        (current, self.blend_cache.blended(step_now, w, current, &fit))
    }

    /// The raw (unblended) fit at `step_now` — the memoized or
    /// refit-every-call path [`Self::forecast_at`] layers blending on.
    fn fit_at(&self, step_now: i64, horizon: usize) -> (f64, Arc<Vec<f64>>) {
        if self.memoize {
            let fit_horizon = horizon.max(self.horizon_steps).max(1);
            return self.cache.fit(
                self.forecaster,
                &self.trace,
                step_now,
                self.lookback_steps,
                fit_horizon,
            );
        }
        let (current, forecast) = crate::grid::cache::fit_once(
            self.forecaster,
            &self.trace,
            step_now,
            self.lookback_steps,
            horizon,
        );
        (current, Arc::new(forecast))
    }
}

/// Per-step memo of the blended forecast (see
/// [`GridShiftConfig::forecast_at`]): within one trace step the blend
/// weight and the underlying fit are constant, so the discounted
/// vector is computed once and every later decision at the step gets
/// an `Arc` clone — the blending analogue of [`ForecastCache`], and
/// like it a lock-free [`Snapshot`] whose clones share the published
/// value: the blended vector is a pure function of `(step, w, fit)`,
/// so sharing is decision-neutral and racing writers publish
/// bit-identical vectors.
struct BlendCache {
    slot: Arc<Snapshot<BlendFit>>,
}

struct BlendFit {
    step: i64,
    w_bits: u64,
    len: usize,
    forecast: Arc<Vec<f64>>,
}

impl BlendCache {
    fn blended(&self, step: i64, w: f64, current: f64, fit: &Arc<Vec<f64>>) -> Arc<Vec<f64>> {
        if let Some(b) = self.slot.get() {
            if b.step == step && b.w_bits == w.to_bits() && b.len == fit.len() {
                return Arc::clone(&b.forecast);
            }
        }
        let blended: Arc<Vec<f64>> =
            Arc::new(fit.iter().map(|&f| (1.0 - w) * f + w * current).collect());
        self.slot.publish(BlendFit {
            step,
            w_bits: w.to_bits(),
            len: fit.len(),
            forecast: Arc::clone(&blended),
        });
        blended
    }
}

impl Default for BlendCache {
    fn default() -> Self {
        BlendCache { slot: Arc::new(Snapshot::new()) }
    }
}

impl Clone for BlendCache {
    fn clone(&self) -> Self {
        BlendCache { slot: Arc::clone(&self.slot) }
    }
}

impl std::fmt::Debug for BlendCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BlendCache").field("cached", &self.slot.get().is_some()).finish()
    }
}

/// The closed-loop corpus plan: routing + release times + batches.
#[derive(Debug, Clone)]
pub struct CorpusPlan {
    /// Device index per prompt (the routing decision).
    pub assignment: Vec<usize>,
    /// Earliest-start time per prompt: the arrival time unless the
    /// deferral planner shifted the prompt into a cleaner window.
    pub release_s: Vec<f64>,
    /// Admission-controlled batches, per-device queues drained in
    /// release order.
    pub batches: Vec<Batch>,
    /// Prompts whose release was shifted past their arrival.
    pub deferred: usize,
}

/// The full placement decision, shared by the closed-loop scheduler,
/// the open-loop DES and the wallclock server.
pub struct PlacementPolicy {
    strategy: Box<dyn Strategy>,
    /// Grid context; `None` restores purely spatial placement.
    pub grid: Option<GridShiftConfig>,
    /// Decision flight recorder. `None` (the default) keeps every
    /// decision path allocation-free: emission sites are guarded by a
    /// single `Option` branch and build their event payloads only on
    /// the enabled arm, so the PR-3/PR-4 hot-path numbers are
    /// unaffected when tracing is off.
    trace: Option<Arc<TraceSink>>,
}

impl PlacementPolicy {
    /// Resolve a strategy name through [`router::build`] — the single
    /// place any plane turns a name into a placement policy. Unknown
    /// names error here, loudly, for every plane.
    pub fn new(strategy: &str, cluster: &Cluster, grid: Option<GridShiftConfig>) -> Result<Self> {
        Ok(PlacementPolicy { strategy: router::build(strategy, cluster)?, grid, trace: None })
    }

    /// A purely spatial policy (no grid context) — the paper's setup.
    pub fn spatial(strategy: &str, cluster: &Cluster) -> Result<Self> {
        Self::new(strategy, cluster, None)
    }

    /// Wrap an already-built strategy.
    pub fn from_strategy(strategy: Box<dyn Strategy>, grid: Option<GridShiftConfig>) -> Self {
        PlacementPolicy { strategy, grid, trace: None }
    }

    /// Attach a decision flight recorder: every routing and deferral
    /// decision made through this policy emits one structured
    /// [`TraceEvent`] to `sink`.
    pub fn with_trace(mut self, sink: Arc<TraceSink>) -> Self {
        self.trace = Some(sink);
        self
    }

    /// The attached flight recorder, if any — planes clone it to stamp
    /// plane-level events (releases, batch launches, replans) into the
    /// same stream as the policy's decisions.
    pub fn trace_sink(&self) -> Option<&Arc<TraceSink>> {
        self.trace.as_ref()
    }

    pub fn name(&self) -> String {
        self.strategy.name()
    }

    pub fn strategy(&self) -> &dyn Strategy {
        self.strategy.as_ref()
    }

    /// Whole-corpus routing (the closed-loop plane).
    pub fn route_corpus(
        &self,
        prompts: &[Prompt],
        cluster: &Cluster,
        db: &BenchmarkDb,
        batch_size: usize,
    ) -> Vec<usize> {
        let ctx = RouteContext { cluster, db, batch_size };
        let assignment = self.strategy.assign(prompts, &ctx);
        if let Some(sink) = &self.trace {
            // corpus routing has no live backlog: the whole corpus is
            // placed at once, so route events carry an empty snapshot
            for (p, &d) in prompts.iter().zip(&assignment) {
                sink.emit(&TraceEvent::Route {
                    t: p.arrival_s,
                    prompt: p.id,
                    device: cluster.devices[d].name.clone(),
                    cells: ctx.cost_cells(p),
                    backlog_s: Vec::new(),
                });
            }
        }
        assignment
    }

    /// On-arrival routing with live per-device backlog (the DES and
    /// wallclock planes).
    pub fn route_arrival(
        &self,
        p: &Prompt,
        cluster: &Cluster,
        db: &BenchmarkDb,
        batch_size: usize,
        backlog_s: &[f64],
        now: f64,
    ) -> usize {
        self.route_arrival_masked(p, cluster, db, batch_size, backlog_s, now, None)
    }

    /// [`Self::route_arrival`] with a device-health mask: Down devices
    /// are excluded from placement, impaired ones pay the mask's
    /// penalty (see [`OnlineView`]). Callers shed *before* routing when
    /// the mask has no routable device ([`HealthMask::any_up`]).
    /// `health: None` is bit-for-bit `route_arrival`.
    #[allow(clippy::too_many_arguments)]
    pub fn route_arrival_masked(
        &self,
        p: &Prompt,
        cluster: &Cluster,
        db: &BenchmarkDb,
        batch_size: usize,
        backlog_s: &[f64],
        now: f64,
        health: Option<&HealthMask>,
    ) -> usize {
        let ctx = RouteContext { cluster, db, batch_size };
        let view = OnlineView { backlog_s, now, grid: self.grid.as_ref(), health };
        let d = self.strategy.route_one(p, &ctx, &view);
        if let Some(sink) = &self.trace {
            sink.emit(&TraceEvent::Route {
                t: now,
                prompt: p.id,
                device: cluster.devices[d].name.clone(),
                cells: ctx.cost_cells(p),
                backlog_s: backlog_s.to_vec(),
            });
        }
        d
    }

    /// Pick the release time for a prompt: the cleanest forecast window
    /// reachable before `arrival + deadline − safety`, or `now` when
    /// the prompt is interactive, deferral is off, there is no slack,
    /// or waiting predicts no benefit. The safety margin covers
    /// worst-case batch occupancy plus the backlog already in the
    /// cluster, so honoring the release time honours the deadline.
    pub fn plan_release(
        &self,
        p: &Prompt,
        cluster: &Cluster,
        db: &BenchmarkDb,
        batch_size: usize,
        backlog_s: f64,
        now: f64,
    ) -> f64 {
        let g = match &self.grid {
            Some(g) if g.defer => g,
            _ => return now,
        };
        let deadline_s = match p.slo.deadline_s() {
            Some(d) => d,
            None => return now,
        };
        let est = min_cost_e2e(p, cluster, db, batch_size);
        // the margin must absorb worst-case batch occupancy, today's
        // backlog, AND the pile-up of other deferred prompts releasing
        // into the same clean window — 10 % of the deadline covers that
        // pile-up generously at any sane load while barely shrinking
        // the set of reachable clean windows
        let safety = (3.0 * batch_size as f64 * est + backlog_s)
            .max(0.10 * deadline_s)
            .max(120.0);
        let latest_start = p.arrival_s + deadline_s - safety;
        let run_steps = ((est * batch_size as f64 / g.trace.step_s).ceil() as usize).max(1);
        // no slack, or no predicted benefit to waiting: run now
        match clean_window(g, latest_start, run_steps, now) {
            Some(w) => {
                if w.release_s > now + 1e-9 {
                    if let Some(sink) = &self.trace {
                        sink.emit(&TraceEvent::Defer {
                            t: now,
                            prompt: p.id,
                            slo: "deferrable".to_string(),
                            deadline_s,
                            release_s: w.release_s,
                            window_g_per_kwh: w.window_g_per_kwh,
                            forecast_hash: crate::grid::forecast_hash(&w.forecast[..w.horizon]),
                            blend_w: g.blend_weight(),
                        });
                    }
                }
                w.release_s
            }
            None => now,
        }
    }

    /// Carbon-aware batch sizing: should `device` launch the partial
    /// batch `queued` now, or hold it for a cleaner window?
    ///
    /// Returns `Some(hold_until)` only when sizing is enabled, the
    /// batch is partial, *every* member is `Deferrable` with slack, and
    /// the forecast predicts a strictly cleaner window inside the
    /// tightest member's deadline bound. The safety margin is priced on
    /// `device` itself (the batch will run there — the cluster's
    /// fastest device is irrelevant to its deadline risk). Any
    /// interactive member — or an interactive arrival during the hold —
    /// forces an immediate launch, so sizing can never delay
    /// interactive traffic.
    #[allow(clippy::too_many_arguments)]
    pub fn plan_batch_hold(
        &self,
        cluster: &Cluster,
        db: &BenchmarkDb,
        prompts: &[Prompt],
        queued: &[usize],
        device: usize,
        batch_size: usize,
        now: f64,
    ) -> Option<f64> {
        self.plan_batch_hold_members(
            cluster,
            db,
            queued.iter().map(|&i| &prompts[i]),
            device,
            batch_size,
            now,
        )
    }

    /// [`Self::plan_batch_hold`] over the member prompts directly —
    /// for planes that hold owned prompts rather than indices into a
    /// corpus slice (the wallclock server's worker loop). Same gates,
    /// same result: `None` unless every member is `Deferrable` with
    /// slack and the batch is partial.
    #[allow(clippy::too_many_arguments)]
    pub fn plan_batch_hold_members<'a>(
        &self,
        cluster: &Cluster,
        db: &BenchmarkDb,
        members: impl IntoIterator<Item = &'a Prompt>,
        device: usize,
        batch_size: usize,
        now: f64,
    ) -> Option<f64> {
        plan_batch_hold_with(self.grid.as_ref()?, cluster, db, members, device, batch_size, now)
    }

    /// Receding-horizon re-plan of a *held* prompt's release at `now`.
    ///
    /// - [`ReplanTrigger::Drift`]: the active forecast has empirically
    ///   diverged from the realized trace, so the promised clean window
    ///   cannot be trusted — the cleanest *trusted* start is now
    ///   (release early).
    /// - [`ReplanTrigger::Cadence`]: re-run [`Self::plan_release`]
    ///   against the fresh per-step fit. The hold may move earlier
    ///   (the clean window evaporated in the new fit) or later (a
    ///   cleaner window appeared), but the result obeys exactly the
    ///   arrival-time bound: never past
    ///   `arrival + deadline − safety`.
    /// - [`ReplanTrigger::DeviceFailed`]: the device the hold was
    ///   planned around went Down — the release is re-planned exactly
    ///   like a cadence pass (the forecast is still trusted; only the
    ///   placement changed), and the prompt re-routes at its release
    ///   instant through the health mask, which excludes the dead
    ///   device. The same deadline bound applies.
    ///
    /// Either way the returned release is `>= now` and `<= max(now,
    /// arrival + deadline − safety)`; since replans only ever run while
    /// the prompt is still held (`now` before the old release, which
    /// was itself inside the bound), a replanned release can never land
    /// past the SLO deadline — property-tested in `tests/planes.rs`.
    ///
    /// The *device* assignment is re-planned implicitly: held prompts
    /// are routed at their release instant ([`Self::route_arrival`]
    /// with live backlog in the DES and wallclock planes), so moving
    /// the release also re-picks the device under the conditions that
    /// will actually hold when it runs.
    #[allow(clippy::too_many_arguments)]
    pub fn replan_release(
        &self,
        trigger: ReplanTrigger,
        p: &Prompt,
        cluster: &Cluster,
        db: &BenchmarkDb,
        batch_size: usize,
        backlog_s: f64,
        now: f64,
    ) -> f64 {
        match trigger {
            ReplanTrigger::Drift => now,
            ReplanTrigger::Cadence | ReplanTrigger::DeviceFailed => {
                self.plan_release(p, cluster, db, batch_size, backlog_s, now)
            }
        }
    }

    /// Receding-horizon re-plan of a pending carbon-sizing hold: the
    /// batch-hold analogue of [`Self::replan_release`]. A drift trigger
    /// cancels the hold (`None` — launch now); a cadence trigger
    /// re-runs [`Self::plan_batch_hold`] with the same deadline gates.
    /// A device-failed trigger also cancels (`None`): the hold was
    /// sized for the dead device, so its members go back through
    /// admission — and health-masked routing — immediately.
    #[allow(clippy::too_many_arguments)]
    pub fn replan_batch_hold(
        &self,
        trigger: ReplanTrigger,
        cluster: &Cluster,
        db: &BenchmarkDb,
        prompts: &[Prompt],
        queued: &[usize],
        device: usize,
        batch_size: usize,
        now: f64,
    ) -> Option<f64> {
        match trigger {
            ReplanTrigger::Drift | ReplanTrigger::DeviceFailed => None,
            ReplanTrigger::Cadence => {
                self.plan_batch_hold(cluster, db, prompts, queued, device, batch_size, now)
            }
        }
    }

    /// [`Self::replan_batch_hold`] over member prompts (the wallclock
    /// worker loop's form): drift cancels the hold, cadence re-plans it.
    #[allow(clippy::too_many_arguments)]
    pub fn replan_batch_hold_members<'a>(
        &self,
        trigger: ReplanTrigger,
        cluster: &Cluster,
        db: &BenchmarkDb,
        members: impl IntoIterator<Item = &'a Prompt>,
        device: usize,
        batch_size: usize,
        now: f64,
    ) -> Option<f64> {
        replan_batch_hold_with(
            trigger,
            self.grid.as_ref()?,
            cluster,
            db,
            members,
            device,
            batch_size,
            now,
        )
    }

    /// The closed-loop corpus plan: route the whole corpus, plan
    /// deferral releases, order each device queue by release time
    /// (SLO-aware ordering) and form admission-controlled batches.
    ///
    /// With no grid context every release equals its arrival and the
    /// order is arrival order — the plan is byte-identical to the
    /// pre-refactor `form_batches(strategy.assign(..))` pipeline.
    pub fn plan_corpus(
        &self,
        prompts: &[Prompt],
        cluster: &Cluster,
        db: &BenchmarkDb,
        batch_size: usize,
        grouping: Grouping,
    ) -> CorpusPlan {
        let assignment = self.route_corpus(prompts, cluster, db, batch_size);
        let mut release_s: Vec<f64> = prompts.iter().map(|p| p.arrival_s).collect();
        let mut deferred = 0usize;
        if matches!(&self.grid, Some(g) if g.defer) {
            // closed-loop "backlog" at plan time: the whole corpus is
            // already queued, so charge each deferral decision the mean
            // per-device share of total estimated work
            let n_dev = cluster.devices.len().max(1);
            let backlog_s: f64 = prompts
                .iter()
                .map(|p| min_cost_e2e(p, cluster, db, batch_size))
                .sum::<f64>()
                / n_dev as f64;
            for (i, p) in prompts.iter().enumerate() {
                let r = self.plan_release(p, cluster, db, batch_size, backlog_s, p.arrival_s);
                if r > p.arrival_s + 1e-9 {
                    release_s[i] = r;
                    deferred += 1;
                }
            }
        }
        let mut order: Vec<usize> = (0..prompts.len()).collect();
        order.sort_by(|&a, &b| {
            release_s[a]
                .partial_cmp(&release_s[b])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        // Batch per release cohort: prompts running at arrival form one
        // cohort, shifted prompts one cohort per release window (trace
        // step). A batch launches at its LATEST member's release, so
        // mixing cohorts would drag interactive prompts into a deferred
        // member's clean window hours away; within one window cohort
        // the spread is below a single trace step, inside every
        // member's safety margin. With no grid every prompt is in the
        // run-at-arrival cohort and this is one plain form_batches
        // pass — the pre-refactor plan, exactly.
        let batches = match &self.grid {
            Some(g) if deferred > 0 => {
                let cohort = |i: usize| -> i64 {
                    if release_s[i] <= prompts[i].arrival_s + 1e-9 {
                        i64::MIN // run-at-arrival cohort
                    } else {
                        g.trace.step_of(release_s[i])
                    }
                };
                let mut cohorts: Vec<(i64, Vec<usize>)> = Vec::new();
                for &i in &order {
                    let key = cohort(i);
                    match cohorts.iter_mut().find(|(k, _)| *k == key) {
                        Some((_, members)) => members.push(i),
                        None => cohorts.push((key, vec![i])),
                    }
                }
                let mut out = Vec::new();
                for (_, members) in &cohorts {
                    out.extend(form_batches_ordered(
                        prompts, &assignment, members, batch_size, cluster, grouping,
                    ));
                }
                out
            }
            _ => form_batches_ordered(prompts, &assignment, &order, batch_size, cluster, grouping),
        };
        if matches!(&self.grid, Some(g) if g.sizing) {
            // carbon-aware batch sizing in the closed loop: each
            // device's TRAILING batch — the only partial one the
            // chunker produces at the queue tail, so holding it delays
            // nothing behind it — may start in a cleaner window when
            // every member is deferrable with slack
            let mut tail: std::collections::BTreeMap<usize, usize> = std::collections::BTreeMap::new();
            for (k, b) in batches.iter().enumerate() {
                tail.insert(b.device, k);
            }
            for &k in tail.values() {
                let batch = &batches[k];
                let ready = batch
                    .members
                    .iter()
                    .map(|&i| release_s[i])
                    .fold(0.0f64, f64::max);
                if let Some(until) = self.plan_batch_hold(
                    cluster,
                    db,
                    prompts,
                    &batch.members,
                    batch.device,
                    batch_size,
                    ready,
                ) {
                    for &i in &batch.members {
                        if until > release_s[i] + 1e-9 {
                            if release_s[i] <= prompts[i].arrival_s + 1e-9 {
                                deferred += 1;
                            }
                            release_s[i] = until;
                        }
                    }
                }
            }
        }
        CorpusPlan { assignment, release_s, batches, deferred }
    }
}

/// The free-function core of carbon-aware batch sizing over member
/// prompts, parameterized by the grid context it plans against.
/// [`PlacementPolicy::plan_batch_hold_members`] passes the policy's
/// own grid; the wallclock server's worker threads instead pass a
/// per-worker *cold clone* of it, so each worker's replan clock,
/// forecast memo and blend state stay thread-local — a worker polling
/// its drift tracker can never consume a trigger the ingest thread's
/// deferral queue is waiting for. Gates are identical either way:
/// `None` unless every member is `Deferrable` with slack and the
/// batch is partial.
#[allow(clippy::too_many_arguments)]
pub fn plan_batch_hold_with<'a>(
    g: &GridShiftConfig,
    cluster: &Cluster,
    db: &BenchmarkDb,
    members: impl IntoIterator<Item = &'a Prompt>,
    device: usize,
    batch_size: usize,
    now: f64,
) -> Option<f64> {
    if !g.sizing {
        return None;
    }
    let mut n = 0usize;
    let mut bound = f64::INFINITY;
    let mut est_max = 0.0f64;
    for p in members {
        n += 1;
        let deadline_s = p.slo.deadline_s()?; // interactive member: launch now
        let est = db.cost_id(DeviceId(device), &cluster.devices[device], p, batch_size).e2e_s;
        est_max = est_max.max(est);
        let safety = (3.0 * batch_size as f64 * est).max(0.05 * deadline_s).max(60.0);
        bound = bound.min(p.arrival_s + deadline_s - safety);
    }
    if n == 0 || n >= batch_size || !bound.is_finite() {
        return None;
    }
    let run_steps = ((est_max * n as f64 / g.trace.step_s).ceil() as usize).max(1);
    clean_window(g, bound, run_steps, now).map(|w| w.release_s)
}

/// At-plan savings estimate of one sizing hold: the members' estimated
/// energy on the executing device, priced at the planned launch
/// (`until`) minus at hold placement (`now`). The single formula both
/// the DES and the wallclock worker post to
/// [`crate::telemetry::EnergyLedger::post_sizing_hold`], so the
/// cross-plane `SizingStats` can never compare two different bases.
pub fn sizing_hold_saving_kg<'a>(
    cluster: &Cluster,
    db: &BenchmarkDb,
    members: impl IntoIterator<Item = &'a Prompt>,
    device: usize,
    batch_size: usize,
    now: f64,
    until: f64,
) -> f64 {
    let kwh: f64 = members
        .into_iter()
        .map(|p| db.cost_id(DeviceId(device), &cluster.devices[device], p, batch_size).energy_kwh)
        .sum();
    cluster.carbon.kg_co2e(kwh, now) - cluster.carbon.kg_co2e(kwh, until)
}

/// The replan form of [`plan_batch_hold_with`]: drift and device
/// failure cancel the hold (launch / re-admit now), cadence re-runs
/// the planner with the same gates.
#[allow(clippy::too_many_arguments)]
pub fn replan_batch_hold_with<'a>(
    trigger: ReplanTrigger,
    g: &GridShiftConfig,
    cluster: &Cluster,
    db: &BenchmarkDb,
    members: impl IntoIterator<Item = &'a Prompt>,
    device: usize,
    batch_size: usize,
    now: f64,
) -> Option<f64> {
    match trigger {
        ReplanTrigger::Drift | ReplanTrigger::DeviceFailed => None,
        ReplanTrigger::Cadence => {
            plan_batch_hold_with(g, cluster, db, members, device, batch_size, now)
        }
    }
}

/// The shared clean-window search: the cleanest forecast window start
/// in `(now, bound]`, or `None` when there is no slack (`bound <= now`)
/// or `now` is already the cleanest reachable start. `run_steps` sizes
/// the averaging window over the forecast. Both the per-prompt deferral
/// planner and the batch-sizing planner resolve through here, so the
/// forecast indexing (`forecast[j]` predicts trace step
/// `step_now + 1 + j` — history ends at `step_now` inclusive) lives in
/// exactly one place. The fit comes from the config's per-step memo
/// ([`GridShiftConfig::forecast_at`]), so the DES no longer refits the
/// forecaster on every arrival.
fn clean_window(
    g: &GridShiftConfig,
    bound: f64,
    run_steps: usize,
    now: f64,
) -> Option<CleanWindow> {
    if bound <= now {
        return None;
    }
    let step = g.trace.step_s;
    let horizon = ((((bound - now) / step).floor() as usize) + 1).min(g.horizon_steps);
    if horizon == 0 {
        return None;
    }
    let step_now = g.trace.step_of(now);
    let (_, forecast) = g.forecast_at(step_now, horizon);
    let (j, mean) =
        shift::best_start_with_mean(&forecast[..horizon], horizon - 1, run_steps.max(1));
    if j == 0 {
        return None;
    }
    Some(CleanWindow {
        release_s: ((step_now + 1 + j as i64) as f64 * step).min(bound).max(now),
        window_g_per_kwh: mean,
        forecast,
        horizon,
    })
}

/// A planned clean window with the evidence the planner saw: the
/// winning window's mean forecast intensity plus the forecast it
/// searched (an `Arc` clone of the per-step memo — no copy). The
/// flight recorder stamps deferral events with both so a trace records
/// not just *where* work moved but *why* — allocation-free on the
/// disabled path because the forecast `Arc` already existed.
struct CleanWindow {
    release_s: f64,
    /// Mean forecast intensity over the chosen run window, g/kWh.
    window_g_per_kwh: f64,
    /// The searched forecast vector (shared with the per-step memo).
    forecast: Arc<Vec<f64>>,
    /// Steps of `forecast` actually searched (the memo may be longer).
    horizon: usize,
}

/// Cheapest estimated per-prompt occupancy across devices.
fn min_cost_e2e(p: &Prompt, cluster: &Cluster, db: &BenchmarkDb, batch_size: usize) -> f64 {
    (0..cluster.devices.len())
        .map(|d| db.cost_id(DeviceId(d), &cluster.devices[d], p, batch_size).e2e_s)
        .fold(f64::MAX, f64::min)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::coordinator::batcher::form_batches;
    use crate::workload::{trace, Corpus, SloClass};

    fn setup(n: usize) -> (Cluster, Vec<Prompt>, BenchmarkDb) {
        let mut cfg = ExperimentConfig::default();
        cfg.workload.prompts = n;
        let cluster = Cluster::from_config(&cfg.cluster);
        let mut corpus = Corpus::generate(&cfg.workload);
        trace::assign_arrivals(&mut corpus.prompts, cfg.workload.arrival, cfg.workload.seed);
        let db = BenchmarkDb::build(&cluster, &[1, 4, 8], 3, 69.0, 1);
        (cluster, corpus.prompts, db)
    }

    fn diurnal_grid() -> GridShiftConfig {
        GridShiftConfig::from_model(
            &CarbonModel::diurnal(69.0, 0.3),
            ForecastKind::Harmonic,
            900.0,
        )
        .expect("diurnal model is time-varying")
    }

    #[test]
    fn unknown_strategy_is_rejected() {
        let (cluster, _, _) = setup(1);
        assert!(PlacementPolicy::spatial("nope", &cluster).is_err());
        assert!(PlacementPolicy::spatial("latency-aware", &cluster).is_ok());
    }

    #[test]
    fn default_plan_matches_prerefactor_pipeline() {
        let (cluster, prompts, db) = setup(60);
        for name in ["latency-aware", "carbon-aware", "round-robin", "all-on-ada-2000"] {
            let policy = PlacementPolicy::spatial(name, &cluster).unwrap();
            let plan = policy.plan_corpus(&prompts, &cluster, &db, 4, Grouping::Fifo);
            let ctx = RouteContext { cluster: &cluster, db: &db, batch_size: 4 };
            let direct = policy.strategy().assign(&prompts, &ctx);
            assert_eq!(plan.assignment, direct, "{name}: routing changed");
            let direct_batches = form_batches(&prompts, &direct, 4, &cluster, Grouping::Fifo);
            assert_eq!(plan.batches, direct_batches, "{name}: batch plan changed");
            assert_eq!(plan.deferred, 0);
            for (r, p) in plan.release_s.iter().zip(&prompts) {
                assert_eq!(*r, p.arrival_s);
            }
        }
    }

    #[test]
    fn plan_release_noop_cases() {
        let (cluster, mut prompts, db) = setup(4);
        let policy =
            PlacementPolicy::new("carbon-aware", &cluster, Some(diurnal_grid())).unwrap();
        // interactive prompts are never shifted
        assert_eq!(
            policy.plan_release(&prompts[0], &cluster, &db, 4, 0.0, prompts[0].arrival_s),
            prompts[0].arrival_s
        );
        // a deadline tighter than the safety margin leaves no slack
        prompts[1].slo = SloClass::Deferrable { deadline_s: 60.0 };
        assert_eq!(
            policy.plan_release(&prompts[1], &cluster, &db, 4, 0.0, prompts[1].arrival_s),
            prompts[1].arrival_s
        );
        // constant grid: waiting predicts no benefit
        let flat = PlacementPolicy::new(
            "carbon-aware",
            &cluster,
            Some(GridShiftConfig::new(GridTrace::constant(69.0), ForecastKind::Persistence)),
        )
        .unwrap();
        prompts[2].slo = SloClass::Deferrable { deadline_s: 8.0 * 3600.0 };
        assert_eq!(
            flat.plan_release(&prompts[2], &cluster, &db, 4, 0.0, prompts[2].arrival_s),
            prompts[2].arrival_s
        );
    }

    #[test]
    fn plan_release_shifts_evening_arrivals_toward_cleaner_hours() {
        let (cluster, mut prompts, db) = setup(4);
        let policy =
            PlacementPolicy::new("carbon-aware", &cluster, Some(diurnal_grid())).unwrap();
        let arrival = 18.0 * 3600.0; // evening ramp
        prompts[0].arrival_s = arrival;
        prompts[0].slo = SloClass::Deferrable { deadline_s: 12.0 * 3600.0 };
        let r = policy.plan_release(&prompts[0], &cluster, &db, 4, 0.0, arrival);
        assert!(r > arrival, "release {r} not shifted");
        // never past the deadline slack
        assert!(r <= arrival + 12.0 * 3600.0);
        // the model is cleaner at the release than at arrival
        let m = CarbonModel::diurnal(69.0, 0.3);
        assert!(m.intensity_at(r) < m.intensity_at(arrival));
    }

    #[test]
    fn batch_hold_respects_gates() {
        let (cluster, mut prompts, db) = setup(8);
        for p in &mut prompts {
            p.arrival_s = 18.0 * 3600.0;
            p.slo = SloClass::Deferrable { deadline_s: 12.0 * 3600.0 };
        }
        let grid = diurnal_grid().with_sizing(true);
        let policy = PlacementPolicy::new("carbon-aware", &cluster, Some(grid)).unwrap();
        let now = 18.0 * 3600.0;

        // a partial all-deferrable batch in the evening ramp holds
        let hold = policy.plan_batch_hold(&cluster, &db, &prompts, &[0, 1], 0, 4, now);
        let until = hold.expect("partial deferrable batch should hold");
        assert!(until > now);
        assert!(until <= now + 12.0 * 3600.0);

        // sizing disabled -> no hold
        let off = PlacementPolicy::new("carbon-aware", &cluster, Some(diurnal_grid())).unwrap();
        assert!(off.plan_batch_hold(&cluster, &db, &prompts, &[0, 1], 0, 4, now).is_none());

        // a full batch launches
        let policy2 = PlacementPolicy::new(
            "carbon-aware",
            &cluster,
            Some(diurnal_grid().with_sizing(true)),
        )
        .unwrap();
        assert!(policy2
            .plan_batch_hold(&cluster, &db, &prompts, &[0, 1, 2, 3], 0, 4, now)
            .is_none());

        // an interactive member forces an immediate launch
        let mut mixed = prompts.clone();
        mixed[1].slo = SloClass::Interactive;
        assert!(policy2.plan_batch_hold(&cluster, &db, &mixed, &[0, 1], 0, 4, now).is_none());

        // the safety bound is priced on the device that will run the
        // batch: a slower device leaves less slack, so its hold can
        // never end later than the faster device's
        let h_jetson = policy2.plan_batch_hold(&cluster, &db, &prompts, &[0, 1], 0, 4, now);
        let h_ada = policy2.plan_batch_hold(&cluster, &db, &prompts, &[0, 1], 1, 4, now);
        if let (Some(hj), Some(ha)) = (h_jetson, h_ada) {
            assert!(hj <= ha + 1e-9, "slower device held longer: {hj} vs {ha}");
        }
    }

    #[test]
    fn corpus_plan_sizing_holds_the_partial_tail_batch() {
        // 5 all-deferrable prompts at batch 4 on one device: the tail
        // batch of 1 is the only partial one — sizing shifts it into a
        // cleaner window without touching the full leading batch
        let (cluster, mut prompts, db) = setup(5);
        for p in &mut prompts {
            p.arrival_s = 18.0 * 3600.0;
            p.slo = SloClass::Deferrable { deadline_s: 12.0 * 3600.0 };
        }
        let base = PlacementPolicy::new(
            "all-on-jetson-orin-nx",
            &cluster,
            Some(diurnal_grid().with_defer(false)),
        )
        .unwrap();
        let sized = PlacementPolicy::new(
            "all-on-jetson-orin-nx",
            &cluster,
            Some(diurnal_grid().with_defer(false).with_sizing(true)),
        )
        .unwrap();
        let a = base.plan_corpus(&prompts, &cluster, &db, 4, Grouping::Fifo);
        let b = sized.plan_corpus(&prompts, &cluster, &db, 4, Grouping::Fifo);
        assert_eq!(a.batches, b.batches, "sizing must not reshape batches");
        assert_eq!(a.deferred, 0);
        let tail = b.batches.last().unwrap();
        assert_eq!(tail.members.len(), 1, "expected a partial tail batch");
        for &i in &tail.members {
            assert!(b.release_s[i] > a.release_s[i], "tail batch not held");
            assert!(b.release_s[i] <= prompts[i].arrival_s + 12.0 * 3600.0);
        }
        for &i in &b.batches[0].members {
            assert_eq!(b.release_s[i], a.release_s[i], "full batch must not move");
        }
        assert_eq!(b.deferred, tail.members.len());
    }

    #[test]
    fn corpus_plan_defers_on_diurnal_grid() {
        let (cluster, mut prompts, db) = setup(40);
        for p in &mut prompts {
            p.arrival_s = 18.0 * 3600.0;
        }
        trace::assign_slos(&mut prompts, 0.5, 12.0 * 3600.0, 7);
        let policy =
            PlacementPolicy::new("carbon-aware", &cluster, Some(diurnal_grid())).unwrap();
        let plan = policy.plan_corpus(&prompts, &cluster, &db, 4, Grouping::Fifo);
        assert!(plan.deferred > 0, "nothing deferred");
        // releases never precede arrivals, and only deferrables move
        for (i, p) in prompts.iter().enumerate() {
            assert!(plan.release_s[i] >= p.arrival_s);
            if !p.slo.is_deferrable() {
                assert_eq!(plan.release_s[i], p.arrival_s);
            }
        }
        // every prompt still appears in exactly one batch
        let mut seen = vec![false; prompts.len()];
        for b in &plan.batches {
            for &m in &b.members {
                assert!(!seen[m]);
                seen[m] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn deferred_prompts_never_share_a_batch_with_interactive() {
        let (cluster, mut prompts, db) = setup(40);
        for p in &mut prompts {
            p.arrival_s = 18.0 * 3600.0;
        }
        trace::assign_slos(&mut prompts, 0.5, 12.0 * 3600.0, 7);
        let policy =
            PlacementPolicy::new("carbon-aware", &cluster, Some(diurnal_grid())).unwrap();
        let plan = policy.plan_corpus(&prompts, &cluster, &db, 4, Grouping::Fifo);
        assert!(plan.deferred > 0, "scenario must defer something");
        let step = policy.grid.as_ref().unwrap().trace.step_s;
        for b in &plan.batches {
            let shifted: Vec<bool> = b
                .members
                .iter()
                .map(|&i| plan.release_s[i] > prompts[i].arrival_s + 1e-9)
                .collect();
            // a batch is entirely run-at-arrival or entirely shifted:
            // an interactive prompt can never wait on a clean window
            assert!(
                shifted.iter().all(|&s| s) || shifted.iter().all(|&s| !s),
                "mixed batch {:?}",
                b.members
            );
            // a shifted batch shares one release window, so no member
            // waits more than a trace step past its own plan
            if shifted[0] {
                let lo = b.members.iter().map(|&i| plan.release_s[i]).fold(f64::MAX, f64::min);
                let hi = b.members.iter().map(|&i| plan.release_s[i]).fold(0.0f64, f64::max);
                assert!(hi - lo <= step + 1e-9, "window spread {} > step", hi - lo);
            }
        }
    }

    #[test]
    fn memoized_forecasts_do_not_change_the_plan() {
        // the hot-path cache must be decision-invisible: an identical
        // corpus plan with memoization on and off, releases included
        let (cluster, mut prompts, db) = setup(40);
        for p in &mut prompts {
            p.arrival_s = 18.0 * 3600.0;
        }
        trace::assign_slos(&mut prompts, 0.5, 12.0 * 3600.0, 7);
        let cached = PlacementPolicy::new(
            "carbon-aware",
            &cluster,
            Some(diurnal_grid().with_sizing(true)),
        )
        .unwrap();
        let refit = PlacementPolicy::new(
            "carbon-aware",
            &cluster,
            Some(diurnal_grid().with_sizing(true).with_memoize(false)),
        )
        .unwrap();
        let a = cached.plan_corpus(&prompts, &cluster, &db, 4, Grouping::Fifo);
        let b = refit.plan_corpus(&prompts, &cluster, &db, 4, Grouping::Fifo);
        assert_eq!(a.assignment, b.assignment);
        assert_eq!(a.release_s, b.release_s, "memoization changed a release");
        assert_eq!(a.batches, b.batches);
        assert_eq!(a.deferred, b.deferred);
        assert!(a.deferred > 0, "scenario must exercise the forecast path");
    }

    #[test]
    fn replan_release_obeys_the_deadline_bound_under_both_triggers() {
        use crate::grid::ReplanTrigger;
        use crate::util::check::property;
        let (cluster, prompts, db) = setup(1);
        let policy =
            PlacementPolicy::new("carbon-aware", &cluster, Some(diurnal_grid())).unwrap();
        let base = prompts[0].clone();
        property("replanned release never passes the deadline", 64, |rng| {
            let mut p = base.clone();
            p.arrival_s = rng.range(0.0, 2.0 * 86_400.0);
            let deadline = rng.range(1800.0, 14.0 * 3600.0);
            p.slo = SloClass::Deferrable { deadline_s: deadline };
            // a replan can only happen while the prompt is still held
            let now = p.arrival_s + rng.range(0.0, deadline * 0.9);
            for trigger in
                [ReplanTrigger::Drift, ReplanTrigger::Cadence, ReplanTrigger::DeviceFailed]
            {
                let r = policy.replan_release(trigger, &p, &cluster, &db, 4, 0.0, now);
                if r < now - 1e-9 {
                    return Err(format!("{trigger:?}: release {r} before now {now}"));
                }
                if r > p.arrival_s + deadline + 1e-9 {
                    return Err(format!(
                        "{trigger:?}: release {r} past deadline {}",
                        p.arrival_s + deadline
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn drift_trigger_releases_now_and_cancels_holds() {
        use crate::grid::ReplanTrigger;
        let (cluster, mut prompts, db) = setup(4);
        for p in &mut prompts {
            p.arrival_s = 18.0 * 3600.0;
            p.slo = SloClass::Deferrable { deadline_s: 12.0 * 3600.0 };
        }
        let policy = PlacementPolicy::new(
            "carbon-aware",
            &cluster,
            Some(diurnal_grid().with_sizing(true)),
        )
        .unwrap();
        let now = 19.0 * 3600.0;
        // cadence keeps planning holds on the (accurate) diurnal grid...
        let cadence = ReplanTrigger::Cadence;
        let r = policy.replan_release(cadence, &prompts[0], &cluster, &db, 4, 0.0, now);
        assert!(r > now, "cadence replan should keep the evening hold");
        assert!(policy
            .replan_batch_hold(cadence, &cluster, &db, &prompts, &[0, 1], 0, 4, now)
            .is_some());
        // ...while a drift trigger releases immediately
        let drift = ReplanTrigger::Drift;
        let r = policy.replan_release(drift, &prompts[0], &cluster, &db, 4, 0.0, now);
        assert_eq!(r, now);
        assert!(policy
            .replan_batch_hold(drift, &cluster, &db, &prompts, &[0, 1], 0, 4, now)
            .is_none());
    }

    #[test]
    fn device_failed_trigger_replans_releases_and_cancels_holds() {
        use crate::grid::ReplanTrigger;
        let (cluster, mut prompts, db) = setup(4);
        for p in &mut prompts {
            p.arrival_s = 18.0 * 3600.0;
            p.slo = SloClass::Deferrable { deadline_s: 12.0 * 3600.0 };
        }
        let policy = PlacementPolicy::new(
            "carbon-aware",
            &cluster,
            Some(diurnal_grid().with_sizing(true)),
        )
        .unwrap();
        let now = 19.0 * 3600.0;
        let t = ReplanTrigger::DeviceFailed;
        // the forecast is still trusted: the release re-plans like a
        // cadence pass (the evening hold survives, on a new device)...
        let r = policy.replan_release(t, &prompts[0], &cluster, &db, 4, 0.0, now);
        assert!(r > now, "device-failed replan should keep the evening hold");
        assert!(r <= prompts[0].arrival_s + 12.0 * 3600.0);
        // ...while a sizing hold — sized for the dead device — cancels
        assert!(policy
            .replan_batch_hold(t, &cluster, &db, &prompts, &[0, 1], 0, 4, now)
            .is_none());
        assert_eq!(t.name(), "device_failed");
    }

    #[test]
    fn masked_route_arrival_avoids_down_devices() {
        use crate::cluster::{HealthMask, HealthState};
        let (cluster, prompts, db) = setup(12);
        let policy = PlacementPolicy::spatial("carbon-aware", &cluster).unwrap();
        let backlog = vec![0.0; cluster.devices.len()];
        for p in &prompts {
            // no mask == bit-for-bit the unmasked entry point
            let bare = policy.route_arrival(p, &cluster, &db, 4, &backlog, p.arrival_s);
            let unmasked = policy
                .route_arrival_masked(p, &cluster, &db, 4, &backlog, p.arrival_s, None);
            assert_eq!(bare, unmasked);
            // masking the chosen device forces a different survivor
            let mut mask = HealthMask::all_up(cluster.devices.len());
            mask.set(bare, HealthState::Down);
            let rerouted = policy.route_arrival_masked(
                p,
                &cluster,
                &db,
                4,
                &backlog,
                p.arrival_s,
                Some(&mask),
            );
            assert_ne!(rerouted, bare);
            assert!(rerouted < cluster.devices.len());
        }
    }

    #[test]
    fn replan_due_is_inert_when_off_and_gated_when_on() {
        let off = diurnal_grid();
        assert!(!off.replan, "replan must default off");
        assert_eq!(off.replan_due(0.0), None);
        assert_eq!(off.replan_due(86_400.0), None);

        let on = diurnal_grid().with_replan(true).with_replan_interval_s(1800.0);
        assert_eq!(on.replan_due(0.0), None, "first call only anchors");
        // the diurnal trace is perfectly forecastable by the harmonic
        // fit, so drift never trips; cadence fires on the interval
        assert_eq!(on.replan_due(900.0), None);
        assert_eq!(on.replan_due(1800.0), Some(crate::grid::ReplanTrigger::Cadence));
        assert_eq!(on.replan_due(1900.0), None, "cadence clock restarted");
    }

    #[test]
    fn blend_off_is_bit_for_bit_the_pure_fit() {
        let off = diurnal_grid();
        assert!(!off.blend, "blend must default off");
        let plain = diurnal_grid();
        for step in [0i64, 7, 70, 71, 140] {
            let (ca, fa) = off.forecast_at(step, 48);
            let (cb, fb) = plain.forecast_at(step, 48);
            assert_eq!(ca.to_bits(), cb.to_bits());
            assert_eq!(fa.len(), fb.len());
            for (x, y) in fa.iter().zip(fb.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn blend_is_identity_while_the_forecast_is_trustworthy() {
        // persistence predicts a constant trace exactly: the rolling
        // MAPE stays 0, so blending must not move a single value
        let constant = || {
            GridShiftConfig::new(GridTrace::constant(69.0), ForecastKind::Persistence)
        };
        let blended = constant().with_blend(true);
        let pure = constant();
        for step in 0..24 {
            let (_, fa) = blended.forecast_at(step, 48);
            let (_, fb) = pure.forecast_at(step, 48);
            for (x, y) in fa.iter().zip(fb.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "step {step}");
            }
        }
    }

    #[test]
    fn blend_discounts_toward_persistence_under_drift() {
        // a level shift the harmonic fit cannot see coming: once the
        // rolling MAPE is non-zero, the blended forecast must sit
        // between the pure fit and flat persistence, reaching exactly
        // persistence when the MAPE crosses the threshold
        let mut samples: Vec<f64> = CarbonModel::diurnal(69.0, 0.3)
            .to_trace(900.0)
            .samples()
            .to_vec();
        let n = samples.len();
        for s in samples.iter_mut().skip(n / 2) {
            *s += 150.0; // the lull the fit never saw
        }
        let trace = GridTrace::new("shifted", 900.0, samples);
        let blended = GridShiftConfig::new(trace.clone(), ForecastKind::Harmonic)
            .with_blend(true)
            .with_drift_threshold(0.05);
        let pure = GridShiftConfig::new(trace.clone(), ForecastKind::Harmonic);
        // walk the tracker up to the shift so it scores the surprise
        let shift_step = (n / 2) as i64;
        for step in (shift_step - 6)..=(shift_step + 2) {
            blended.forecast_at(step, 48);
        }
        let probe = shift_step + 3;
        let (current, fb) = blended.forecast_at(probe, 48);
        let (_, fp) = pure.forecast_at(probe, 48);
        assert!(
            fb.iter().zip(fp.iter()).any(|(b, p)| b != p),
            "drift never moved the blend"
        );
        // every blended value lies on the segment [fit, persistence]
        for (b, p) in fb.iter().zip(fp.iter()) {
            let lo = p.min(current) - 1e-9;
            let hi = p.max(current) + 1e-9;
            assert!(*b >= lo && *b <= hi, "blend {b} outside [{lo}, {hi}]");
        }
        // the +150 level shift dwarfs the 0.05 threshold: the weight
        // saturates and the forecast is pure persistence — flat at the
        // current observed sample
        for b in fb.iter() {
            assert!((b - current).abs() < 1e-9, "saturated blend {b} != current {current}");
        }
    }

    #[test]
    fn blend_curves_agree_at_the_endpoints_and_order_in_between() {
        let threshold = 0.2;
        for curve in BlendCurve::ALL {
            assert_eq!(curve.weight(0.0, threshold), 0.0, "{}", curve.name());
            assert_eq!(curve.weight(threshold, threshold), 1.0, "{}", curve.name());
            assert_eq!(curve.weight(10.0 * threshold, threshold), 1.0, "{}", curve.name());
        }
        // between the endpoints: step never discounts, quadratic
        // discounts less than linear (gentler on benign noise)
        for r in [0.1, 0.25, 0.5, 0.75, 0.9] {
            let mape = r * threshold;
            let lin = BlendCurve::Linear.weight(mape, threshold);
            let quad = BlendCurve::ClampedQuadratic.weight(mape, threshold);
            let step = BlendCurve::Step.weight(mape, threshold);
            assert_eq!(step, 0.0, "step curve discounted below threshold");
            assert!((lin - r).abs() < 1e-12);
            assert!((quad - r * r).abs() < 1e-12);
            assert!(quad < lin, "quadratic must undercut linear at r={r}");
        }
        assert_eq!(BlendCurve::default(), BlendCurve::ClampedQuadratic);
    }

    #[test]
    fn blend_curve_changes_the_partial_discount_but_not_saturation() {
        // same drift-injected trace as the discount test; at a probe
        // step where the weight has saturated, every curve agrees
        // (flat persistence), while a small-MAPE step separates them
        let trace = GridTrace::new("ramp", 900.0, {
            let mut s = vec![70.0; 40];
            s.extend(vec![220.0; 40]);
            s
        });
        let mk = |curve: BlendCurve| {
            GridShiftConfig::new(trace.clone(), ForecastKind::Harmonic)
                .with_blend(true)
                .with_blend_curve(curve)
                .with_drift_threshold(0.05)
        };
        for curve in BlendCurve::ALL {
            let g = mk(curve);
            for step in 36..44 {
                g.forecast_at(step, 24);
            }
            let (current, f) = g.forecast_at(44, 24);
            for b in f.iter() {
                assert!(
                    (b - current).abs() < 1e-9,
                    "{}: saturated blend {b} != persistence {current}",
                    curve.name()
                );
            }
        }
    }

    #[test]
    fn blended_planning_still_defers_and_respects_deadlines() {
        let (cluster, mut prompts, db) = setup(4);
        let policy = PlacementPolicy::new(
            "carbon-aware",
            &cluster,
            Some(diurnal_grid().with_blend(true)),
        )
        .unwrap();
        let arrival = 18.0 * 3600.0;
        prompts[0].arrival_s = arrival;
        prompts[0].slo = SloClass::Deferrable { deadline_s: 12.0 * 3600.0 };
        let r = policy.plan_release(&prompts[0], &cluster, &db, 4, 0.0, arrival);
        assert!(r > arrival, "blend-on planning lost the evening shift");
        assert!(r <= arrival + 12.0 * 3600.0);
    }

    #[test]
    fn from_model_rejects_constant() {
        assert!(GridShiftConfig::from_model(
            &CarbonModel::constant(69.0),
            ForecastKind::Harmonic,
            900.0
        )
        .is_none());
        assert!(GridShiftConfig::from_model(
            &CarbonModel::diurnal(69.0, 0.3),
            ForecastKind::Harmonic,
            900.0
        )
        .is_some());
    }
}
