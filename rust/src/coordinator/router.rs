//! Routing strategies — the paper's §3 contribution plus extensions.
//!
//! Paper strategies:
//! - **all-on-\<device\>** — greedy single-device baselines ("All on
//!   Jetson", "All on Ada" in Table 3);
//! - **carbon-aware** — each prompt goes to the device with the lower
//!   *measured* carbon footprint for its profile, "prioritizing emission
//!   reduction even if it increases latency";
//! - **latency-aware** — "sorts prompts by decreasing average latency
//!   and assigns them to minimize total end-to-end execution time"
//!   (LPT list scheduling onto earliest-finishing device).
//!
//! Extensions (paper's intro/future work):
//! - **round-robin** — load-oblivious control;
//! - **complexity-aware** — CS-threshold routing (simple → efficient
//!   device, complex → capable device), the intro's "hybrid paradigm";
//! - **carbon-cap** — latency-aware subject to a carbon budget: greedily
//!   spends a carbon allowance where it buys the most speedup;
//! - **forecast-carbon-aware** — prices each (device, start-time) pair
//!   with *forecast* grid intensity at the projected execution time
//!   (the grid subsystem's spatial+temporal strategy): under a
//!   time-varying carbon model, placing a prompt on a device also picks
//!   *when* it runs, and this strategy is the first to exploit that.
//!
//! Every strategy is a pure function from (prompts, context) to a device
//! assignment — property-tested for totality and bounds.

use crate::cluster::{Cluster, HealthMask};
use crate::grid::{ForecastKind, Forecaster};
use crate::telemetry::trace::CostCell;
use crate::workload::Prompt;
use anyhow::{anyhow, bail, Result};

use super::estimator::{BenchmarkDb, CostEstimate, DeviceId};
use super::policy::GridShiftConfig;

/// Routing context handed to strategies.
pub struct RouteContext<'a> {
    pub cluster: &'a Cluster,
    pub db: &'a BenchmarkDb,
    /// Batch size the serving layer will use (costs are batch-dependent).
    pub batch_size: usize,
}

impl RouteContext<'_> {
    /// Hot-path cost lookup by interned device id: O(1) indexing in the
    /// benchmark DB's precomputed cost table, no allocation. Every
    /// strategy prices devices through here.
    #[inline]
    pub fn cost(&self, d: DeviceId, p: &Prompt) -> CostEstimate {
        self.db.cost_id(d, &self.cluster.devices[d.0], p, self.batch_size)
    }

    /// Snapshot every device's cost-table cells for `p` — the flight
    /// recorder's route-event payload (`route.cells`). Allocates, so it
    /// is only ever called on the trace-enabled branch; the routing hot
    /// path never consults it.
    pub fn cost_cells(&self, p: &Prompt) -> Vec<CostCell> {
        (0..self.cluster.devices.len())
            .map(|d| {
                let c = self.cost(DeviceId(d), p);
                CostCell {
                    device: self.cluster.devices[d].name.clone(),
                    e2e_s: c.e2e_s,
                    energy_kwh: c.energy_kwh,
                    carbon_kg: c.carbon_kg,
                }
            })
            .collect()
    }
}

/// Live cluster view for on-arrival routing (the DES and wallclock
/// planes): per-device backlog, current time, and the optional grid
/// context for forecast-priced placement.
pub struct OnlineView<'a> {
    /// Estimated backlog seconds per device.
    pub backlog_s: &'a [f64],
    /// Current time (virtual DES time, or scaled wallclock), seconds.
    pub now: f64,
    /// Grid context, when the plane plans against a forecast.
    pub grid: Option<&'a GridShiftConfig>,
    /// Device health, when the plane tracks churn: Down devices are
    /// excluded from placement, impaired ones pay the mask's penalty.
    /// `None` (the default everywhere churn is off) routes bit-for-bit
    /// identically to the pre-churn path.
    pub health: Option<&'a HealthMask>,
}

impl OnlineView<'_> {
    /// Wrap a per-device price with this view's health mask: Down
    /// devices price to `f64::INFINITY` (never chosen while any device
    /// is routable), impaired devices are multiplied by the mask's
    /// degraded penalty, and Up devices price unchanged. Without a
    /// mask the price passes through untouched — bit-for-bit the
    /// pre-churn path. Callers shed *before* routing when no device is
    /// routable ([`HealthMask::any_up`]); on an all-down mask the
    /// argmin over all-infinite prices still totals (device 0 wins).
    fn priced<'f>(
        &'f self,
        mut f: impl FnMut(usize) -> f64 + 'f,
    ) -> impl FnMut(usize) -> f64 + 'f {
        move |d| match self.health {
            None => f(d),
            Some(m) if m.is_down(d) => f64::INFINITY,
            Some(m) => f(d) * m.penalty(d),
        }
    }
}

/// Post-route health check for fixed-placement strategies (all-on-*,
/// round-robin) whose preferred device ignores load and health: if the
/// mask marks the pick Down, fail over to the surviving device with
/// the cheapest masked carbon price. No mask, or a pick that is not
/// Down, returns the preferred device untouched.
fn fail_over(preferred: usize, p: &Prompt, ctx: &RouteContext, view: &OnlineView) -> usize {
    match view.health {
        Some(m) if m.is_down(preferred) => argmin(
            ctx.cluster.devices.len(),
            view.priced(|d| ctx.cost(DeviceId(d), p).carbon_kg),
        ),
        _ => preferred,
    }
}

/// A routing strategy: returns one device index per prompt.
pub trait Strategy: Send + Sync {
    fn name(&self) -> String;
    fn assign(&self, prompts: &[Prompt], ctx: &RouteContext) -> Vec<usize>;

    /// On-arrival routing of a single prompt with live backlog — the
    /// online form every serving plane consults through
    /// [`super::policy::PlacementPolicy::route_arrival`]. The default
    /// applies the batch semantics to a one-prompt corpus, which is
    /// exact for per-prompt strategies; load- and forecast-aware
    /// strategies override it. All forms honour the view's health mask:
    /// price-based strategies exclude Down devices in the argmin, fixed
    /// strategies fail over post-hoc via [`fail_over`].
    fn route_one(&self, p: &Prompt, ctx: &RouteContext, view: &OnlineView) -> usize {
        fail_over(self.assign(std::slice::from_ref(p), ctx)[0], p, ctx, view)
    }
}

/// Baseline: everything on one device.
pub struct AllOn {
    pub device_index: usize,
    pub device_name: String,
}

impl Strategy for AllOn {
    fn name(&self) -> String {
        format!("all-on-{}", self.device_name)
    }
    fn assign(&self, prompts: &[Prompt], _ctx: &RouteContext) -> Vec<usize> {
        vec![self.device_index; prompts.len()]
    }
}

/// Paper strategy (i): minimize measured carbon per prompt.
pub struct CarbonAware;

impl Strategy for CarbonAware {
    fn name(&self) -> String {
        "carbon-aware".into()
    }
    fn assign(&self, prompts: &[Prompt], ctx: &RouteContext) -> Vec<usize> {
        prompts
            .iter()
            .map(|p| argmin(ctx.cluster.devices.len(), |d| ctx.cost(DeviceId(d), p).carbon_kg))
            .collect()
    }
    /// Online form: same carbon argmin, priced through the health mask.
    fn route_one(&self, p: &Prompt, ctx: &RouteContext, view: &OnlineView) -> usize {
        argmin(
            ctx.cluster.devices.len(),
            view.priced(|d| ctx.cost(DeviceId(d), p).carbon_kg),
        )
    }
}

/// Paper strategy (ii): LPT list scheduling on estimated latency.
///
/// Prompts are sorted by decreasing estimated latency (on their fastest
/// device); each is then placed on the device whose projected finish
/// time after adding it is smallest. This is the greedy makespan
/// heuristic the paper describes.
pub struct LatencyAware;

impl Strategy for LatencyAware {
    fn name(&self) -> String {
        "latency-aware".into()
    }
    fn assign(&self, prompts: &[Prompt], ctx: &RouteContext) -> Vec<usize> {
        let n_dev = ctx.cluster.devices.len();
        // per-prompt per-device amortized cost
        let costs: Vec<Vec<f64>> = prompts
            .iter()
            .map(|p| (0..n_dev).map(|d| ctx.cost(DeviceId(d), p).e2e_s).collect())
            .collect();
        // LPT order: hardest first (by min-device cost)
        let mut order: Vec<usize> = (0..prompts.len()).collect();
        order.sort_by(|&a, &b| {
            let ka = costs[a].iter().cloned().fold(f64::MAX, f64::min);
            let kb = costs[b].iter().cloned().fold(f64::MAX, f64::min);
            kb.partial_cmp(&ka).unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut load = vec![0.0f64; n_dev];
        let mut out = vec![0usize; prompts.len()];
        for idx in order {
            let d = argmin(n_dev, |d| load[d] + costs[idx][d]);
            load[d] += costs[idx][d];
            out[idx] = d;
        }
        out
    }
    /// Online form: earliest projected finish = live backlog + this
    /// prompt's estimated cost (the paper's greedy heuristic applied
    /// on arrival).
    fn route_one(&self, p: &Prompt, ctx: &RouteContext, view: &OnlineView) -> usize {
        argmin(
            ctx.cluster.devices.len(),
            view.priced(|d| view.backlog_s[d] + ctx.cost(DeviceId(d), p).e2e_s),
        )
    }
}

/// Extension: load-oblivious round-robin control.
pub struct RoundRobin;

impl Strategy for RoundRobin {
    fn name(&self) -> String {
        "round-robin".into()
    }
    fn assign(&self, prompts: &[Prompt], ctx: &RouteContext) -> Vec<usize> {
        let n = ctx.cluster.devices.len();
        (0..prompts.len()).map(|i| i % n).collect()
    }
    /// Online form: rotate on the prompt id (stable across planes),
    /// failing over when the rotation lands on a Down device.
    fn route_one(&self, p: &Prompt, ctx: &RouteContext, view: &OnlineView) -> usize {
        fail_over((p.id as usize) % ctx.cluster.devices.len(), p, ctx, view)
    }
}

/// Extension: complexity-threshold routing (the intro's heuristic).
/// Simple prompts (CS < threshold) go to the most energy-efficient
/// device; complex ones to the fastest device.
pub struct ComplexityAware {
    pub threshold: f64,
}

impl Strategy for ComplexityAware {
    fn name(&self) -> String {
        format!("complexity-aware@{:.2}", self.threshold)
    }
    fn assign(&self, prompts: &[Prompt], ctx: &RouteContext) -> Vec<usize> {
        // rank devices once using a reference mid-corpus prompt profile
        let probe = |p: &Prompt, d: usize| ctx.cost(DeviceId(d), p);
        prompts
            .iter()
            .map(|p| {
                if p.complexity < self.threshold {
                    argmin(ctx.cluster.devices.len(), |d| probe(p, d).carbon_kg)
                } else {
                    argmin(ctx.cluster.devices.len(), |d| probe(p, d).e2e_s)
                }
            })
            .collect()
    }
    /// Online form: the same threshold split, priced through the
    /// health mask.
    fn route_one(&self, p: &Prompt, ctx: &RouteContext, view: &OnlineView) -> usize {
        let n = ctx.cluster.devices.len();
        if p.complexity < self.threshold {
            argmin(n, view.priced(|d| ctx.cost(DeviceId(d), p).carbon_kg))
        } else {
            argmin(n, view.priced(|d| ctx.cost(DeviceId(d), p).e2e_s))
        }
    }
}

/// Extension (future work): latency-aware under a carbon budget.
///
/// Start from the carbon-minimal assignment, then greedily re-route the
/// prompts with the best latency-saved-per-extra-carbon ratio until the
/// budget (kgCO2e above the carbon-minimal baseline) is exhausted.
pub struct CarbonCap {
    /// Extra carbon allowed above the carbon-minimal total, kgCO2e.
    pub budget_kg: f64,
}

impl Strategy for CarbonCap {
    fn name(&self) -> String {
        format!("carbon-cap@{:.2e}", self.budget_kg)
    }
    fn assign(&self, prompts: &[Prompt], ctx: &RouteContext) -> Vec<usize> {
        let n_dev = ctx.cluster.devices.len();
        let cost = |p: &Prompt, d: usize| ctx.cost(DeviceId(d), p);
        // start carbon-minimal
        let mut assign: Vec<usize> =
            prompts.iter().map(|p| argmin(n_dev, |d| cost(p, d).carbon_kg)).collect();
        // candidate moves: (latency saved per carbon spent, idx, target)
        let mut moves: Vec<(f64, f64, usize, usize)> = Vec::new();
        for (i, p) in prompts.iter().enumerate() {
            let cur = cost(p, assign[i]);
            for d in 0..n_dev {
                if d == assign[i] {
                    continue;
                }
                let alt = cost(p, d);
                let saved = cur.e2e_s - alt.e2e_s;
                let extra = alt.carbon_kg - cur.carbon_kg;
                if saved > 0.0 && extra > 0.0 {
                    moves.push((saved / extra, extra, i, d));
                }
            }
        }
        moves.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
        let mut budget = self.budget_kg;
        let mut moved = vec![false; prompts.len()];
        for (_, extra, i, d) in moves {
            if moved[i] || extra > budget {
                continue;
            }
            budget -= extra;
            assign[i] = d;
            moved[i] = true;
        }
        assign
    }

    /// Online form: the budget is a *corpus-level* allowance with no
    /// meaningful per-arrival split (granting every arrival the full
    /// budget would overrun the cap by up to N×), so the online planes
    /// spend nothing and place carbon-minimally — the cap is honoured
    /// by construction.
    fn route_one(&self, p: &Prompt, ctx: &RouteContext, view: &OnlineView) -> usize {
        argmin(
            ctx.cluster.devices.len(),
            view.priced(|d| ctx.cost(DeviceId(d), p).carbon_kg),
        )
    }
}

/// Extension (grid subsystem): forecast-priced spatio-temporal routing.
///
/// The cluster's carbon model doubles as the observed grid signal: the
/// strategy samples its past (two days up to the first arrival), fits
/// the configured forecaster, and then greedily places prompts — in
/// LPT order, mirroring [`LatencyAware`] — on the device minimizing
/// `energy × forecast intensity at the projected mid-execution time`
/// given the load already packed onto that device. Under a constant
/// model this degenerates to carbon-aware placement; under a diurnal or
/// trace model it trades devices *and* hours.
pub struct ForecastCarbonAware {
    pub forecaster: ForecastKind,
    /// Discretization of the forecast curve, seconds.
    pub step_s: f64,
}

impl Strategy for ForecastCarbonAware {
    fn name(&self) -> String {
        format!("forecast-carbon-aware@{}", self.forecaster.name())
    }
    fn assign(&self, prompts: &[Prompt], ctx: &RouteContext) -> Vec<usize> {
        let n_dev = ctx.cluster.devices.len();
        let t0 = prompts.iter().map(|p| p.arrival_s).fold(f64::INFINITY, f64::min);
        let t0 = if t0.is_finite() { t0 } else { 0.0 };
        // flatten the cluster's carbon model into the planning trace the
        // grid subsystem already knows how to sample and forecast
        let planning = ctx.cluster.carbon.to_trace(self.step_s);
        let steps_per_day = planning.steps_per_day();
        let step0 = planning.step_of(t0);
        let history = planning.history(step0, 2 * steps_per_day);
        let current = history.last().copied().unwrap_or(0.0);
        let forecast = self.forecaster.build(steps_per_day).forecast(&history, 2 * steps_per_day);
        // forecast[k] predicts trace step `step0 + 1 + k`; offsets inside
        // the current step use the observed current sample
        let intensity_after = |dt: f64| -> f64 {
            let ahead = planning.step_of(t0 + dt.max(0.0)) - step0;
            if ahead <= 0 {
                current
            } else {
                forecast[(ahead as usize - 1).min(forecast.len() - 1)]
            }
        };

        let costs: Vec<Vec<CostEstimate>> = prompts
            .iter()
            .map(|p| (0..n_dev).map(|d| ctx.cost(DeviceId(d), p)).collect())
            .collect();
        // LPT order (hardest first), then place at the cheapest
        // projected (device, start-time) carbon price
        let mut order: Vec<usize> = (0..prompts.len()).collect();
        order.sort_by(|&a, &b| {
            let ka = costs[a].iter().map(|c| c.e2e_s).fold(f64::MAX, f64::min);
            let kb = costs[b].iter().map(|c| c.e2e_s).fold(f64::MAX, f64::min);
            kb.partial_cmp(&ka).unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut load = vec![0.0f64; n_dev];
        let mut out = vec![0usize; prompts.len()];
        for idx in order {
            let d = argmin(n_dev, |d| {
                let c = &costs[idx][d];
                c.energy_kwh * intensity_after(load[d] + 0.5 * c.e2e_s)
            });
            load[d] += costs[idx][d].e2e_s;
            out[idx] = d;
        }
        out
    }

    /// Online form: price each device at its projected mid-execution
    /// step (`now + backlog + e2e/2`) under the forecast fitted on the
    /// grid trace's history up to now. The fit comes from the grid
    /// context's per-step memo ([`GridShiftConfig::forecast_at`]), so
    /// under memoization (the default) the forecaster refits once per
    /// trace step rather than once per routing decision — same
    /// decisions, orders of magnitude fewer fits on the DES hot path.
    /// An execution landing inside the current step uses the observed
    /// current sample. Without a grid context this degenerates to
    /// arrival-time carbon pricing.
    fn route_one(&self, p: &Prompt, ctx: &RouteContext, view: &OnlineView) -> usize {
        let n = ctx.cluster.devices.len();
        let g = match view.grid {
            Some(g) => g,
            None => return argmin(n, view.priced(|d| ctx.cost(DeviceId(d), p).carbon_kg)),
        };
        let step_now = g.trace.step_of(view.now);
        let cap = g.horizon_steps.max(1);
        // forecast steps ahead of the device's projected mid-execution
        let ahead_of = |d: usize, c: &CostEstimate| -> usize {
            let exec_t = view.now + view.backlog_s[d] + 0.5 * c.e2e_s;
            ((g.trace.step_of(exec_t) - step_now).max(0) as usize).min(cap)
        };
        // two passes over the (O(1), allocation-free) cost table rather
        // than one pass that heap-allocates per decision: this IS the
        // per-arrival hot path
        let max_ahead =
            (0..n).map(|d| ahead_of(d, &ctx.cost(DeviceId(d), p))).max().unwrap_or(0);
        let (current, forecast) = g.forecast_at(step_now, max_ahead);
        argmin(
            n,
            view.priced(|d| {
                let c = ctx.cost(DeviceId(d), p);
                let ahead = ahead_of(d, &c);
                let intensity = if ahead == 0 { current } else { forecast[ahead - 1] };
                c.energy_kwh * intensity
            }),
        )
    }
}

/// Build a strategy from its config name.
///
/// Recognized: `all-on-<device-name>`, `carbon-aware`, `latency-aware`,
/// `round-robin`, `complexity-aware[@threshold]`, `carbon-cap@<kg>`,
/// `forecast-carbon-aware[@<forecaster>]`.
pub fn build(name: &str, cluster: &Cluster) -> Result<Box<dyn Strategy>> {
    if let Some(dev) = name.strip_prefix("all-on-") {
        let idx = cluster
            .device_index(dev)
            .ok_or_else(|| anyhow!("unknown device '{dev}' in strategy '{name}'"))?;
        return Ok(Box::new(AllOn { device_index: idx, device_name: dev.to_string() }));
    }
    if name == "carbon-aware" {
        return Ok(Box::new(CarbonAware));
    }
    if name == "latency-aware" {
        return Ok(Box::new(LatencyAware));
    }
    if name == "round-robin" {
        return Ok(Box::new(RoundRobin));
    }
    if name == "complexity-aware" {
        return Ok(Box::new(ComplexityAware { threshold: 0.35 }));
    }
    if let Some(t) = name.strip_prefix("complexity-aware@") {
        let threshold: f64 = t.parse().map_err(|_| anyhow!("bad threshold in '{name}'"))?;
        return Ok(Box::new(ComplexityAware { threshold }));
    }
    if let Some(b) = name.strip_prefix("carbon-cap@") {
        let budget_kg: f64 = b.parse().map_err(|_| anyhow!("bad budget in '{name}'"))?;
        return Ok(Box::new(CarbonCap { budget_kg }));
    }
    if name == "forecast-carbon-aware" {
        return Ok(Box::new(ForecastCarbonAware {
            forecaster: ForecastKind::Harmonic,
            step_s: 900.0,
        }));
    }
    if let Some(f) = name.strip_prefix("forecast-carbon-aware@") {
        let forecaster = ForecastKind::parse(f)
            .ok_or_else(|| anyhow!("unknown forecaster '{f}' in '{name}'"))?;
        return Ok(Box::new(ForecastCarbonAware { forecaster, step_s: 900.0 }));
    }
    bail!(
        "unknown strategy '{name}' (all-on-<device>|carbon-aware|latency-aware|\
         round-robin|complexity-aware[@t]|carbon-cap@<kg>|forecast-carbon-aware[@f])"
    )
}

fn argmin(n: usize, mut f: impl FnMut(usize) -> f64) -> usize {
    assert!(n > 0);
    let mut best = 0;
    let mut best_v = f(0);
    for i in 1..n {
        let v = f(i);
        if v < best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::coordinator::estimator::BenchmarkDb;
    use crate::util::check::property;
    use crate::util::rng::Rng;
    use crate::workload::{Category, Corpus};

    fn setup() -> (Cluster, BenchmarkDb) {
        let cluster = Cluster::from_config(&ExperimentConfig::default().cluster);
        let db = BenchmarkDb::build(&cluster, &[1, 4, 8], 3, 69.0, 1);
        (cluster, db)
    }

    fn prompts(n: usize, seed: u64) -> Vec<crate::workload::Prompt> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| {
                let cat = Category::ALL[rng.below(8)];
                Corpus::sample_prompt(i as u64, cat, &mut rng)
            })
            .collect()
    }

    #[test]
    fn all_strategies_total_and_in_bounds() {
        let (cluster, db) = setup();
        let names = [
            "all-on-jetson-orin-nx",
            "all-on-ada-2000",
            "carbon-aware",
            "latency-aware",
            "round-robin",
            "complexity-aware",
            "complexity-aware@0.5",
            "carbon-cap@1e-5",
            "forecast-carbon-aware",
            "forecast-carbon-aware@seasonal-naive",
        ];
        property("assignment totality", 24, |rng| {
            let n = rng.below(40) + 1;
            let ps = prompts(n, rng.next_u64());
            for name in names {
                let s = build(name, &cluster).unwrap();
                let ctx = RouteContext { cluster: &cluster, db: &db, batch_size: rng.below(8) + 1 };
                let a = s.assign(&ps, &ctx);
                if a.len() != n {
                    return Err(format!("{name}: len {} != {n}", a.len()));
                }
                if a.iter().any(|&d| d >= cluster.devices.len()) {
                    return Err(format!("{name}: device index out of bounds"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn all_on_is_constant() {
        let (cluster, db) = setup();
        let s = build("all-on-ada-2000", &cluster).unwrap();
        let ps = prompts(10, 3);
        let ctx = RouteContext { cluster: &cluster, db: &db, batch_size: 4 };
        assert!(s.assign(&ps, &ctx).iter().all(|&d| d == 1));
    }

    #[test]
    fn carbon_aware_prefers_jetson() {
        // Table-2 physics: the Jetson wins carbon almost everywhere
        let (cluster, db) = setup();
        let s = CarbonAware;
        let ps = prompts(200, 5);
        let ctx = RouteContext { cluster: &cluster, db: &db, batch_size: 4 };
        let a = s.assign(&ps, &ctx);
        let jetson_share = a.iter().filter(|&&d| d == 0).count() as f64 / a.len() as f64;
        assert!(jetson_share > 0.7, "share={jetson_share}");
    }

    #[test]
    fn latency_aware_uses_both_devices() {
        let (cluster, db) = setup();
        let s = LatencyAware;
        let ps = prompts(100, 7);
        let ctx = RouteContext { cluster: &cluster, db: &db, batch_size: 4 };
        let a = s.assign(&ps, &ctx);
        let jetson = a.iter().filter(|&&d| d == 0).count();
        assert!(jetson > 0 && jetson < a.len(), "jetson={jetson}/{}", a.len());
    }

    #[test]
    fn latency_aware_beats_single_device_makespan() {
        let (cluster, db) = setup();
        let ps = prompts(120, 11);
        let ctx = RouteContext { cluster: &cluster, db: &db, batch_size: 4 };
        let makespan = |assign: &[usize]| {
            let mut load = vec![0.0; cluster.devices.len()];
            for (i, &d) in assign.iter().enumerate() {
                load[d] += db.cost(&cluster.devices[d], &ps[i], 4).e2e_s;
            }
            load.iter().cloned().fold(0.0, f64::max)
        };
        let la = makespan(&LatencyAware.assign(&ps, &ctx));
        let jetson_only = makespan(&vec![0usize; ps.len()]);
        let ada_only = makespan(&vec![1usize; ps.len()]);
        assert!(la < jetson_only && la < ada_only, "{la} vs {jetson_only}/{ada_only}");
    }

    #[test]
    fn complexity_threshold_splits() {
        let (cluster, db) = setup();
        let ps = prompts(200, 13);
        let ctx = RouteContext { cluster: &cluster, db: &db, batch_size: 4 };
        let low = ComplexityAware { threshold: 0.0 }.assign(&ps, &ctx); // all "complex"
        let high = ComplexityAware { threshold: 1.1 }.assign(&ps, &ctx); // all "simple"
        assert_ne!(low, high);
        // all-simple == carbon-minimal assignment
        let carbon = CarbonAware.assign(&ps, &ctx);
        assert_eq!(high, carbon);
    }

    #[test]
    fn carbon_cap_interpolates() {
        let (cluster, db) = setup();
        let ps = prompts(80, 17);
        let ctx = RouteContext { cluster: &cluster, db: &db, batch_size: 4 };
        let total_carbon = |assign: &[usize]| -> f64 {
            assign
                .iter()
                .enumerate()
                .map(|(i, &d)| db.cost(&cluster.devices[d], &ps[i], 4).carbon_kg)
                .sum()
        };
        let zero = CarbonCap { budget_kg: 0.0 }.assign(&ps, &ctx);
        let min_carbon = total_carbon(&CarbonAware.assign(&ps, &ctx));
        assert!((total_carbon(&zero) - min_carbon).abs() < 1e-12);
        let big = CarbonCap { budget_kg: 1.0 }.assign(&ps, &ctx);
        // unlimited budget must not exceed baseline + budget, and should
        // spend some of it (routing some prompts to the fast device)
        assert!(total_carbon(&big) >= min_carbon);
        let moved = big.iter().zip(&zero).filter(|(a, b)| a != b).count();
        assert!(moved > 0);
    }

    #[test]
    fn carbon_cap_respects_budget() {
        let (cluster, db) = setup();
        let ps = prompts(60, 19);
        let ctx = RouteContext { cluster: &cluster, db: &db, batch_size: 4 };
        let total_carbon = |assign: &[usize]| -> f64 {
            assign
                .iter()
                .enumerate()
                .map(|(i, &d)| db.cost(&cluster.devices[d], &ps[i], 4).carbon_kg)
                .sum()
        };
        let min_carbon = total_carbon(&CarbonAware.assign(&ps, &ctx));
        for budget in [1e-7, 1e-6, 1e-5] {
            let a = CarbonCap { budget_kg: budget }.assign(&ps, &ctx);
            assert!(
                total_carbon(&a) <= min_carbon + budget + 1e-12,
                "budget {budget} violated"
            );
        }
    }

    #[test]
    fn build_rejects_unknown() {
        let (cluster, _) = setup();
        assert!(build("nope", &cluster).is_err());
        assert!(build("all-on-unknown-device", &cluster).is_err());
        assert!(build("complexity-aware@abc", &cluster).is_err());
        assert!(build("forecast-carbon-aware@lstm", &cluster).is_err());
    }

    #[test]
    fn forecast_carbon_aware_degenerates_under_constant_grid() {
        // constant intensity cancels out of the price: the strategy must
        // pick the carbon-minimal device for every prompt
        let (cluster, db) = setup();
        let ps = prompts(80, 23);
        let ctx = RouteContext { cluster: &cluster, db: &db, batch_size: 4 };
        let fca = build("forecast-carbon-aware", &cluster).unwrap().assign(&ps, &ctx);
        let ca = CarbonAware.assign(&ps, &ctx);
        assert_eq!(fca, ca);
    }

    #[test]
    fn route_one_matches_online_semantics() {
        let (cluster, db) = setup();
        let ps = prompts(6, 31);
        let ctx = RouteContext { cluster: &cluster, db: &db, batch_size: 4 };
        let idle = vec![0.0; cluster.devices.len()];

        // per-prompt strategies: the online form equals the batch form
        for name in ["carbon-aware", "all-on-ada-2000", "complexity-aware"] {
            let s = build(name, &cluster).unwrap();
            let batch = s.assign(&ps, &ctx);
            for (i, p) in ps.iter().enumerate() {
                let view = OnlineView { backlog_s: &idle, now: 0.0, grid: None, health: None };
                assert_eq!(s.route_one(p, &ctx, &view), batch[i], "{name} prompt {i}");
            }
        }

        // round-robin rotates on the id, not the (single-element) index
        let rr = build("round-robin", &cluster).unwrap();
        let view = OnlineView { backlog_s: &idle, now: 0.0, grid: None, health: None };
        for p in &ps {
            assert_eq!(rr.route_one(p, &ctx, &view), (p.id as usize) % cluster.devices.len());
        }

        // latency-aware avoids the backlogged device
        let la = build("latency-aware", &cluster).unwrap();
        for target in 0..cluster.devices.len() {
            let mut backlog = vec![1e6; cluster.devices.len()];
            backlog[target] = 0.0;
            let view = OnlineView { backlog_s: &backlog, now: 0.0, grid: None, health: None };
            assert_eq!(la.route_one(&ps[0], &ctx, &view), target);
        }

        // forecast-carbon-aware without a grid degenerates to carbon
        let fca = build("forecast-carbon-aware", &cluster).unwrap();
        let ca = build("carbon-aware", &cluster).unwrap();
        let view = OnlineView { backlog_s: &idle, now: 0.0, grid: None, health: None };
        for p in &ps {
            assert_eq!(fca.route_one(p, &ctx, &view), ca.route_one(p, &ctx, &view));
        }

        // carbon-cap online spends nothing (the budget is corpus-level):
        // placement is carbon-minimal, so the cap cannot be overrun
        let cap = build("carbon-cap@1.0", &cluster).unwrap();
        for p in &ps {
            assert_eq!(cap.route_one(p, &ctx, &view), ca.route_one(p, &ctx, &view));
        }
    }

    #[test]
    fn route_one_with_grid_is_deterministic_and_in_bounds() {
        use crate::cluster::CarbonModel;
        use crate::coordinator::policy::GridShiftConfig;
        let (cluster, db) = setup();
        let ps = prompts(10, 37);
        let ctx = RouteContext { cluster: &cluster, db: &db, batch_size: 4 };
        let grid = GridShiftConfig::new(
            CarbonModel::diurnal(69.0, 0.3).to_trace(900.0),
            ForecastKind::Harmonic,
        );
        let fca = build("forecast-carbon-aware", &cluster).unwrap();
        let backlog = vec![120.0, 30.0];
        for p in &ps {
            let view = OnlineView {
                backlog_s: &backlog,
                now: 17.0 * 3600.0,
                grid: Some(&grid),
                health: None,
            };
            let a = fca.route_one(p, &ctx, &view);
            let b = fca.route_one(p, &ctx, &view);
            assert_eq!(a, b);
            assert!(a < cluster.devices.len());
        }
    }

    #[test]
    fn route_one_memoized_matches_refit_path() {
        use crate::cluster::CarbonModel;
        use crate::coordinator::policy::GridShiftConfig;
        let (cluster, db) = setup();
        let ps = prompts(40, 41);
        let ctx = RouteContext { cluster: &cluster, db: &db, batch_size: 4 };
        let trace = CarbonModel::diurnal(69.0, 0.3).to_trace(900.0);
        let cached = GridShiftConfig::new(trace.clone(), ForecastKind::Harmonic);
        let refit = GridShiftConfig::new(trace, ForecastKind::Harmonic).with_memoize(false);
        let fca = build("forecast-carbon-aware", &cluster).unwrap();
        for (k, p) in ps.iter().enumerate() {
            // sweep across trace steps and backlogs (cache hits + misses)
            let now = k as f64 * 1370.0;
            let backlog = vec![(k % 5) as f64 * 60.0, (k % 3) as f64 * 240.0];
            let a = fca.route_one(
                p,
                &ctx,
                &OnlineView { backlog_s: &backlog, now, grid: Some(&cached), health: None },
            );
            let b = fca.route_one(
                p,
                &ctx,
                &OnlineView { backlog_s: &backlog, now, grid: Some(&refit), health: None },
            );
            assert_eq!(a, b, "memoized routing diverged at prompt {k}, t={now}");
        }
    }

    #[test]
    fn health_mask_none_is_bitwise_neutral() {
        // `health: None` must reproduce the pre-churn decisions exactly,
        // for every strategy, on every prompt
        use crate::cluster::HealthMask;
        let (cluster, db) = setup();
        let ps = prompts(30, 43);
        let ctx = RouteContext { cluster: &cluster, db: &db, batch_size: 4 };
        let backlog = vec![45.0, 250.0];
        let all_up = HealthMask::all_up(cluster.devices.len());
        let names = [
            "all-on-jetson-orin-nx",
            "carbon-aware",
            "latency-aware",
            "round-robin",
            "complexity-aware",
            "carbon-cap@1e-5",
            "forecast-carbon-aware",
        ];
        for name in names {
            let s = build(name, &cluster).unwrap();
            for p in &ps {
                let bare = OnlineView { backlog_s: &backlog, now: 0.0, grid: None, health: None };
                let masked = OnlineView {
                    backlog_s: &backlog,
                    now: 0.0,
                    grid: None,
                    health: Some(&all_up),
                };
                assert_eq!(
                    s.route_one(p, &ctx, &bare),
                    s.route_one(p, &ctx, &masked),
                    "{name}: all-up mask changed a decision"
                );
            }
        }
    }

    #[test]
    fn health_mask_excludes_down_devices() {
        use crate::cluster::{HealthMask, HealthState};
        let (cluster, db) = setup();
        let ps = prompts(20, 47);
        let ctx = RouteContext { cluster: &cluster, db: &db, batch_size: 4 };
        let idle = vec![0.0; cluster.devices.len()];
        let names = [
            "all-on-jetson-orin-nx",
            "carbon-aware",
            "latency-aware",
            "round-robin",
            "complexity-aware",
            "carbon-cap@1e-5",
            "forecast-carbon-aware",
        ];
        for down in 0..cluster.devices.len() {
            let mut mask = HealthMask::all_up(cluster.devices.len());
            mask.set(down, HealthState::Down);
            let view = OnlineView { backlog_s: &idle, now: 0.0, grid: None, health: Some(&mask) };
            for name in names {
                let s = build(name, &cluster).unwrap();
                for p in &ps {
                    let d = s.route_one(p, &ctx, &view);
                    assert_ne!(d, down, "{name} routed to the Down device {down}");
                    assert!(d < cluster.devices.len());
                }
            }
        }
    }

    #[test]
    fn health_mask_penalizes_degraded_devices() {
        use crate::cluster::{HealthMask, HealthState};
        let (cluster, db) = setup();
        let ps = prompts(50, 53);
        let ctx = RouteContext { cluster: &cluster, db: &db, batch_size: 4 };
        let idle = vec![0.0; cluster.devices.len()];
        // carbon-aware prefers the jetson (device 0); a huge degraded
        // penalty on it must flip those decisions to the ada
        let mut mask = HealthMask::all_up(cluster.devices.len()).with_degraded_penalty(1e9);
        mask.set(0, HealthState::Degraded);
        let view = OnlineView { backlog_s: &idle, now: 0.0, grid: None, health: Some(&mask) };
        let s = CarbonAware;
        for p in &ps {
            assert_eq!(s.route_one(p, &ctx, &view), 1, "degraded penalty not applied");
        }
        // Recovering is penalized the same way
        mask.set(0, HealthState::Recovering);
        let view = OnlineView { backlog_s: &idle, now: 0.0, grid: None, health: Some(&mask) };
        for p in &ps {
            assert_eq!(s.route_one(p, &ctx, &view), 1);
        }
    }

    #[test]
    fn forecast_carbon_aware_fails_over_with_grid_context() {
        // the key PR-8 scenario: the forecast-priced strategy must not
        // collapse when its cleanest device goes Down mid-run
        use crate::cluster::{CarbonModel, HealthMask, HealthState};
        use crate::coordinator::policy::GridShiftConfig;
        let (cluster, db) = setup();
        let ps = prompts(20, 59);
        let ctx = RouteContext { cluster: &cluster, db: &db, batch_size: 4 };
        let grid = GridShiftConfig::new(
            CarbonModel::diurnal(69.0, 0.3).to_trace(900.0),
            ForecastKind::Harmonic,
        );
        let mut mask = HealthMask::all_up(cluster.devices.len());
        mask.set(0, HealthState::Down); // the jetson: its usual winner
        let backlog = vec![0.0; cluster.devices.len()];
        let view = OnlineView {
            backlog_s: &backlog,
            now: 17.0 * 3600.0,
            grid: Some(&grid),
            health: Some(&mask),
        };
        let s = build("forecast-carbon-aware", &cluster).unwrap();
        for p in &ps {
            assert_eq!(s.route_one(p, &ctx, &view), 1);
        }
    }

    #[test]
    fn forecast_carbon_aware_prices_hours_under_diurnal_grid() {
        use crate::cluster::CarbonModel;
        // a dirty->clean step trace: queueing into the later (cleaner)
        // hours must make the strategy spread work differently than
        // arrival-time carbon-aware does
        let (mut cluster, db) = setup();
        cluster.carbon = CarbonModel::diurnal(69.0, 0.3).into();
        let mut ps = prompts(300, 29);
        for p in &mut ps {
            p.arrival_s = 17.0 * 3600.0; // the evening ramp
        }
        let ctx = RouteContext { cluster: &cluster, db: &db, batch_size: 4 };
        let s = build("forecast-carbon-aware", &cluster).unwrap();
        let a = s.assign(&ps, &ctx);
        assert_eq!(a.len(), ps.len());
        assert!(a.iter().all(|&d| d < cluster.devices.len()));
        // determinism
        assert_eq!(a, s.assign(&ps, &ctx));
    }
}
