//! Network link model for the cloud inference point.
//!
//! The paper's Fig. 1 compares edge devices against the Gemini 2.0 Flash
//! API and attributes the cloud's poor showing on short factual prompts
//! (P4) to "bandwidth and dispatch overheads". We model exactly those:
//! a fixed RTT, serialization time over a finite uplink/downlink, and a
//! provider-side dispatch overhead.

/// Simple symmetric link: fixed RTT + bandwidth-limited transfer.
#[derive(Debug, Clone)]
pub struct LinkModel {
    /// Round-trip time, milliseconds.
    pub rtt_ms: f64,
    /// Link bandwidth, megabits per second.
    pub bandwidth_mbps: f64,
}

impl LinkModel {
    pub fn new(rtt_ms: f64, bandwidth_mbps: f64) -> Self {
        assert!(rtt_ms >= 0.0 && bandwidth_mbps > 0.0);
        Self { rtt_ms, bandwidth_mbps }
    }

    /// Time to move `bytes` one way, seconds (no RTT component).
    pub fn transfer_s(&self, bytes: usize) -> f64 {
        (bytes as f64 * 8.0) / (self.bandwidth_mbps * 1e6)
    }

    /// One-way propagation, seconds.
    pub fn one_way_s(&self) -> f64 {
        self.rtt_ms / 2.0 / 1000.0
    }

    /// Total network time for a request/response exchange: upload the
    /// prompt, download the response, plus one RTT of handshaking.
    pub fn round_trip_s(&self, upload_bytes: usize, download_bytes: usize) -> f64 {
        self.rtt_ms / 1000.0 + self.transfer_s(upload_bytes) + self.transfer_s(download_bytes)
    }

    /// Network time for a prompt/response measured in tokens (~4 bytes
    /// of UTF-8 per token on average for English text + JSON overhead).
    pub fn token_round_trip_s(&self, prompt_tokens: usize, output_tokens: usize) -> f64 {
        const BYTES_PER_TOKEN: usize = 6; // text + protocol framing
        self.round_trip_s(prompt_tokens * BYTES_PER_TOKEN, output_tokens * BYTES_PER_TOKEN)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_scales_with_size() {
        let l = LinkModel::new(80.0, 50.0);
        // 1 MB over 50 Mbps = 8e6 bits / 5e7 bps = 0.16 s
        assert!((l.transfer_s(1_000_000) - 0.16).abs() < 1e-9);
        assert_eq!(l.transfer_s(0), 0.0);
    }

    #[test]
    fn round_trip_includes_rtt() {
        let l = LinkModel::new(100.0, 1000.0);
        assert!(l.round_trip_s(0, 0) >= 0.1);
        assert!(l.round_trip_s(1000, 1000) > l.round_trip_s(0, 0));
    }

    #[test]
    fn short_prompt_dominated_by_rtt() {
        // the Fig. 1 effect: for P4-sized prompts the RTT dwarfs transfer
        let l = LinkModel::new(80.0, 50.0);
        let t = l.token_round_trip_s(10, 12);
        let rtt = 0.08;
        assert!((t - rtt) / t < 0.01, "t={t}");
    }

    #[test]
    #[should_panic]
    fn zero_bandwidth_rejected() {
        LinkModel::new(10.0, 0.0);
    }
}
