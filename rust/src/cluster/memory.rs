//! GPU memory model: weights + KV cache + activations vs capacity.
//!
//! Drives two paper behaviours:
//! - admission control: a batch whose projected footprint exceeds
//!   capacity is rejected/split before dispatch;
//! - the batch-8 instability on the 8 GB Jetson ("errors due to memory
//!   saturation", §3): utilization beyond `saturation_start` degrades
//!   throughput and raises the failure-injection probability.
//!
//! Footprints model the *paper's* models (Gemma-3-1B/12B qat) rather
//! than our miniature artifacts — the simulator works at paper scale.

/// Memory footprint model for one device + the model it serves.
#[derive(Debug, Clone)]
pub struct MemoryModel {
    /// Total GPU memory, GB.
    pub capacity_gb: f64,
    /// Resident model weights, GB (quantized checkpoint + runtime).
    pub weights_gb: f64,
    /// KV-cache per token per sequence, MB.
    pub kv_mb_per_token: f64,
    /// Activation scratch per in-flight sequence, MB.
    pub activation_mb_per_seq: f64,
    /// Utilization fraction where degradation begins (e.g. 0.85).
    pub saturation_start: f64,
}

impl MemoryModel {
    /// Projected footprint for a batch, GB.
    pub fn footprint_gb(&self, batch_size: usize, max_seq_tokens: usize) -> f64 {
        let kv = batch_size as f64 * max_seq_tokens as f64 * self.kv_mb_per_token / 1024.0;
        let act = batch_size as f64 * self.activation_mb_per_seq / 1024.0;
        self.weights_gb + kv + act
    }

    /// Utilization fraction for a batch (can exceed 1.0 = would OOM).
    pub fn utilization(&self, batch_size: usize, max_seq_tokens: usize) -> f64 {
        self.footprint_gb(batch_size, max_seq_tokens) / self.capacity_gb
    }

    /// Whether the batch fits at all.
    pub fn fits(&self, batch_size: usize, max_seq_tokens: usize) -> bool {
        self.utilization(batch_size, max_seq_tokens) <= 1.0
    }

    /// Saturation overshoot in [0, ∞): 0 below `saturation_start`,
    /// rising linearly past it. Feeds the latency degradation and the
    /// failure-injection probability.
    pub fn saturation(&self, batch_size: usize, max_seq_tokens: usize) -> f64 {
        let u = self.utilization(batch_size, max_seq_tokens);
        ((u - self.saturation_start) / (1.0 - self.saturation_start).max(1e-9)).max(0.0)
    }

    /// Largest batch of sequences with `max_seq_tokens` that fits.
    pub fn max_batch(&self, max_seq_tokens: usize) -> usize {
        let mut b = 0;
        while self.fits(b + 1, max_seq_tokens) && b < 1024 {
            b += 1;
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Jetson Orin NX 8 GB serving Gemma-3-1B-qat (~1.3 GB resident
    /// incl. runtime) — generous KV per token for a 1B model.
    fn jetson() -> MemoryModel {
        MemoryModel {
            capacity_gb: 8.0,
            weights_gb: 1.6,
            kv_mb_per_token: 0.75,
            activation_mb_per_seq: 320.0,
            saturation_start: 0.80,
        }
    }

    #[test]
    fn footprint_monotone_in_batch_and_seq() {
        let m = jetson();
        assert!(m.footprint_gb(4, 512) > m.footprint_gb(1, 512));
        assert!(m.footprint_gb(4, 1024) > m.footprint_gb(4, 512));
    }

    #[test]
    fn batch8_long_sequences_saturate_jetson() {
        let m = jetson();
        // batch 8 × 1024-token sequences: 1.6 + 8*1024*0.75/1024 + 8*0.3125
        // = 1.6 + 6.0 + 2.5 = 10.1 GB > 8 GB -> does not fit
        assert!(!m.fits(8, 1024));
        // batch 4 fits but sits in the saturation zone
        assert!(m.fits(4, 1024));
        assert!(m.saturation(4, 1024) >= 0.0);
        // batch 1 is comfortable
        assert!(m.utilization(1, 1024) < 0.5);
        assert_eq!(m.saturation(1, 256), 0.0);
    }

    #[test]
    fn max_batch_consistent_with_fits() {
        let m = jetson();
        let b = m.max_batch(1024);
        assert!(m.fits(b, 1024));
        assert!(!m.fits(b + 1, 1024));
    }

    #[test]
    fn saturation_zero_below_threshold_positive_above() {
        let m = jetson();
        assert_eq!(m.saturation(1, 128), 0.0);
        let heavy = m.saturation(7, 1024);
        assert!(heavy > 0.0, "sat={heavy}");
    }

    #[test]
    fn utilization_can_exceed_one() {
        let m = jetson();
        assert!(m.utilization(16, 2048) > 1.0);
    }
}
