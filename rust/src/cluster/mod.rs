//! Hardware models for the heterogeneous edge cluster.
//!
//! The paper's testbed is an NVIDIA Jetson Orin NX (8 GB) serving
//! Gemma-3-1B-qat and an NVIDIA Ada 2000 (16 GB) serving Gemma-3-12B-qat,
//! plus a cloud API point. We reproduce it as explicit models:
//!
//! - [`device::DeviceProfile`] — one per cluster device: identity,
//!   memory, power, and the latency calibration anchors fitted to the
//!   paper's Table 2;
//! - [`power::PowerModel`] — idle + batch-dependent active draw (watts);
//! - [`carbon::CarbonModel`] — grid intensity (gCO2e/kWh), optionally
//!   diurnal, converting kWh to kgCO2e exactly as the paper does;
//! - [`memory::MemoryModel`] — weights + KV-cache + activation footprint
//!   against GPU capacity (drives admission and the batch-8 saturation
//!   behaviour on the 8 GB device);
//! - [`network::LinkModel`] — RTT/bandwidth in front of the cloud point;
//! - [`health::HealthState`] / [`health::HealthMask`] — per-device
//!   availability (Up → Degraded → Down → Recovering) driven by the
//!   churn subsystem; the router excludes Down devices and penalizes
//!   impaired ones.

pub mod carbon;
pub mod device;
pub mod health;
pub mod memory;
pub mod network;
pub mod power;

pub use carbon::CarbonModel;
pub use device::DeviceProfile;
pub use health::{HealthMask, HealthState};
pub use memory::MemoryModel;
pub use network::LinkModel;
pub use power::PowerModel;

use std::sync::Arc;

use crate::config::{CarbonModelConfig, ClusterConfig, DeviceKind};
use crate::grid::{GridTrace, SyntheticTrace};

/// A fully-instantiated cluster: device profiles + shared carbon model
/// + the network link used by cloud-kind devices.
///
/// The carbon model is behind an `Arc`: trace-backed models carry a
/// full intensity time series, and every `EnergyLedger` shares the
/// cluster's model by reference count instead of deep-cloning the
/// trace per run.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub devices: Vec<DeviceProfile>,
    pub carbon: Arc<CarbonModel>,
    pub link: LinkModel,
}

impl Cluster {
    /// Build profiles from config using the Table-2 calibration tables.
    pub fn from_config(cfg: &ClusterConfig) -> Self {
        let devices = cfg
            .devices
            .iter()
            .map(|d| DeviceProfile::from_config(d))
            .collect();
        Cluster {
            devices,
            carbon: Arc::new(build_carbon_model(&cfg.carbon)),
            link: LinkModel::new(cfg.cloud.rtt_ms, cfg.cloud.bandwidth_mbps),
        }
    }

    pub fn device(&self, name: &str) -> Option<&DeviceProfile> {
        self.devices.iter().find(|d| d.name == name)
    }

    pub fn device_index(&self, name: &str) -> Option<usize> {
        self.devices.iter().position(|d| d.name == name)
    }

    /// Devices of a given kind (e.g. all Jetsons in a scaled cluster).
    pub fn by_kind(&self, kind: DeviceKind) -> Vec<&DeviceProfile> {
        self.devices.iter().filter(|d| d.kind == kind).collect()
    }
}

/// Instantiate the configured carbon model (validated by
/// `ExperimentConfig::validate`).
pub fn build_carbon_model(cfg: &CarbonModelConfig) -> CarbonModel {
    match cfg {
        CarbonModelConfig::Constant { g_per_kwh } => CarbonModel::constant(*g_per_kwh),
        CarbonModelConfig::Diurnal { mean_g_per_kwh, swing } => {
            CarbonModel::diurnal(*mean_g_per_kwh, *swing)
        }
        CarbonModelConfig::Trace { step_s, samples } => {
            CarbonModel::from_trace(GridTrace::new("config-trace", *step_s, samples.clone()))
        }
        CarbonModelConfig::Synthetic {
            mean_g_per_kwh,
            swing,
            weekly_swing,
            noise,
            days,
            step_s,
            seed,
        } => CarbonModel::from_trace(
            SyntheticTrace {
                name: "config-synthetic".into(),
                mean_g_per_kwh: *mean_g_per_kwh,
                diurnal_swing: *swing,
                weekly_swing: *weekly_swing,
                noise_frac: *noise,
                days: *days,
                step_s: *step_s,
                seed: *seed,
            }
            .generate(),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    #[test]
    fn builds_paper_testbed() {
        let cfg = ExperimentConfig::default();
        let cluster = Cluster::from_config(&cfg.cluster);
        assert_eq!(cluster.devices.len(), 2);
        assert!(cluster.device("jetson-orin-nx").is_some());
        assert!(cluster.device("ada-2000").is_some());
        assert_eq!(cluster.by_kind(DeviceKind::Jetson).len(), 1);
        assert_eq!(cluster.device_index("ada-2000"), Some(1));
        assert_eq!(cluster.device_index("nope"), None);
    }

    #[test]
    fn config_carbon_models_instantiate() {
        use crate::config::CarbonModelConfig;

        let mut cfg = ExperimentConfig::default();
        cfg.cluster.carbon =
            CarbonModelConfig::Diurnal { mean_g_per_kwh: 69.0, swing: 0.3 };
        let cluster = Cluster::from_config(&cfg.cluster);
        // diurnal: midday cleaner than evening
        assert!(
            cluster.carbon.intensity_at(13.0 * 3600.0)
                < cluster.carbon.intensity_at(19.0 * 3600.0)
        );

        cfg.cluster.carbon =
            CarbonModelConfig::Trace { step_s: 1800.0, samples: vec![30.0, 90.0] };
        let cluster = Cluster::from_config(&cfg.cluster);
        assert_eq!(cluster.carbon.intensity_at(0.0), 30.0);
        assert_eq!(cluster.carbon.intensity_at(1800.0), 90.0);

        cfg.cluster.carbon = CarbonModelConfig::Synthetic {
            mean_g_per_kwh: 69.0,
            swing: 0.3,
            weekly_swing: 0.1,
            noise: 0.05,
            days: 2,
            step_s: 900.0,
            seed: 9,
        };
        let a = Cluster::from_config(&cfg.cluster);
        let b = Cluster::from_config(&cfg.cluster);
        // deterministic per seed
        assert_eq!(a.carbon.intensity_at(12_345.0), b.carbon.intensity_at(12_345.0));
    }
}
