//! Hardware models for the heterogeneous edge cluster.
//!
//! The paper's testbed is an NVIDIA Jetson Orin NX (8 GB) serving
//! Gemma-3-1B-qat and an NVIDIA Ada 2000 (16 GB) serving Gemma-3-12B-qat,
//! plus a cloud API point. We reproduce it as explicit models:
//!
//! - [`device::DeviceProfile`] — one per cluster device: identity,
//!   memory, power, and the latency calibration anchors fitted to the
//!   paper's Table 2;
//! - [`power::PowerModel`] — idle + batch-dependent active draw (watts);
//! - [`carbon::CarbonModel`] — grid intensity (gCO2e/kWh), optionally
//!   diurnal, converting kWh to kgCO2e exactly as the paper does;
//! - [`memory::MemoryModel`] — weights + KV-cache + activation footprint
//!   against GPU capacity (drives admission and the batch-8 saturation
//!   behaviour on the 8 GB device);
//! - [`network::LinkModel`] — RTT/bandwidth in front of the cloud point.

pub mod carbon;
pub mod device;
pub mod memory;
pub mod network;
pub mod power;

pub use carbon::CarbonModel;
pub use device::DeviceProfile;
pub use memory::MemoryModel;
pub use network::LinkModel;
pub use power::PowerModel;

use crate::config::{ClusterConfig, DeviceKind};

/// A fully-instantiated cluster: device profiles + shared carbon model
/// + the network link used by cloud-kind devices.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub devices: Vec<DeviceProfile>,
    pub carbon: CarbonModel,
    pub link: LinkModel,
}

impl Cluster {
    /// Build profiles from config using the Table-2 calibration tables.
    pub fn from_config(cfg: &ClusterConfig) -> Self {
        let devices = cfg
            .devices
            .iter()
            .map(|d| DeviceProfile::from_config(d))
            .collect();
        Cluster {
            devices,
            carbon: CarbonModel::constant(cfg.carbon_intensity_g_per_kwh),
            link: LinkModel::new(cfg.cloud.rtt_ms, cfg.cloud.bandwidth_mbps),
        }
    }

    pub fn device(&self, name: &str) -> Option<&DeviceProfile> {
        self.devices.iter().find(|d| d.name == name)
    }

    pub fn device_index(&self, name: &str) -> Option<usize> {
        self.devices.iter().position(|d| d.name == name)
    }

    /// Devices of a given kind (e.g. all Jetsons in a scaled cluster).
    pub fn by_kind(&self, kind: DeviceKind) -> Vec<&DeviceProfile> {
        self.devices.iter().filter(|d| d.kind == kind).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    #[test]
    fn builds_paper_testbed() {
        let cfg = ExperimentConfig::default();
        let cluster = Cluster::from_config(&cfg.cluster);
        assert_eq!(cluster.devices.len(), 2);
        assert!(cluster.device("jetson-orin-nx").is_some());
        assert!(cluster.device("ada-2000").is_some());
        assert_eq!(cluster.by_kind(DeviceKind::Jetson).len(), 1);
        assert_eq!(cluster.device_index("ada-2000"), Some(1));
        assert_eq!(cluster.device_index("nope"), None);
    }
}
