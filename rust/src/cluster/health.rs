//! Per-device health state for churn-aware scheduling.
//!
//! [`HealthState`] is the four-state availability machine the churn
//! subsystem drives (Up → Degraded → Down → Recovering → Up);
//! [`HealthMask`] is the cluster-wide view the router consumes: Down
//! devices are excluded from placement entirely, Degraded/Recovering
//! devices stay routable but pay a multiplicative cost penalty. With
//! no mask attached (`health: None` in the router's `OnlineView`)
//! routing is bit-for-bit the pre-churn path.
//!
//! The state machine is driven two ways: in the simulated planes by a
//! `simulator::failure::ChurnSchedule` (scripted outage windows or
//! stochastic MTBF/MTTR sampling), and in the wallclock server by the
//! health-checker thread's heartbeat timeouts.

use std::fmt;

/// One device's availability state.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HealthState {
    /// Fully available.
    #[default]
    Up,
    /// Still serving but impaired (heading into an outage): routing
    /// penalizes it instead of excluding it.
    Degraded,
    /// Unavailable: routing excludes it and in-flight work is killed.
    Down,
    /// Back after an outage but not yet trusted: penalized like
    /// [`HealthState::Degraded`].
    Recovering,
}

impl HealthState {
    /// True for [`HealthState::Down`] only.
    pub fn is_down(self) -> bool {
        matches!(self, HealthState::Down)
    }

    /// Penalized-but-routable states (Degraded, Recovering).
    pub fn is_impaired(self) -> bool {
        matches!(self, HealthState::Degraded | HealthState::Recovering)
    }

    /// Stable lowercase name (used in trace events and reports).
    pub fn name(self) -> &'static str {
        match self {
            HealthState::Up => "up",
            HealthState::Degraded => "degraded",
            HealthState::Down => "down",
            HealthState::Recovering => "recovering",
        }
    }
}

impl fmt::Display for HealthState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Default multiplicative routing-cost factor for impaired devices.
pub const DEFAULT_DEGRADED_PENALTY: f64 = 2.0;

/// Cluster-wide health view consumed by the router: one
/// [`HealthState`] per device plus the impaired-cost factor.
#[derive(Debug, Clone, PartialEq)]
pub struct HealthMask {
    states: Vec<HealthState>,
    degraded_penalty: f64,
}

impl HealthMask {
    /// A mask with every device Up (the neutral starting point).
    pub fn all_up(n: usize) -> Self {
        HealthMask {
            states: vec![HealthState::Up; n],
            degraded_penalty: DEFAULT_DEGRADED_PENALTY,
        }
    }

    /// Override the impaired-device cost factor (must be >= 1).
    pub fn with_degraded_penalty(mut self, factor: f64) -> Self {
        assert!(factor >= 1.0, "degraded penalty must be >= 1, got {factor}");
        self.degraded_penalty = factor;
        self
    }

    /// Number of devices covered by the mask.
    pub fn len(&self) -> usize {
        self.states.len()
    }

    /// True when the mask covers no devices.
    pub fn is_empty(&self) -> bool {
        self.states.is_empty()
    }

    /// The state of one device.
    pub fn state(&self, device: usize) -> HealthState {
        self.states[device]
    }

    /// Set one device's state.
    pub fn set(&mut self, device: usize, state: HealthState) {
        self.states[device] = state;
    }

    /// Is the device excluded from placement?
    pub fn is_down(&self, device: usize) -> bool {
        self.states[device].is_down()
    }

    /// Multiplicative routing-cost factor for a device: 1.0 when Up,
    /// the degraded penalty when impaired. Meaningless for Down
    /// devices — those must be excluded, not priced.
    pub fn penalty(&self, device: usize) -> f64 {
        if self.states[device].is_impaired() {
            self.degraded_penalty
        } else {
            1.0
        }
    }

    /// Number of devices that are not Down.
    pub fn up_count(&self) -> usize {
        self.states.iter().filter(|s| !s.is_down()).count()
    }

    /// Is at least one device routable?
    pub fn any_up(&self) -> bool {
        self.states.iter().any(|s| !s.is_down())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_state_is_up() {
        assert_eq!(HealthState::default(), HealthState::Up);
        assert!(!HealthState::Up.is_down());
        assert!(!HealthState::Up.is_impaired());
    }

    #[test]
    fn state_predicates() {
        assert!(HealthState::Down.is_down());
        assert!(!HealthState::Down.is_impaired());
        assert!(HealthState::Degraded.is_impaired());
        assert!(HealthState::Recovering.is_impaired());
        assert!(!HealthState::Degraded.is_down());
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(HealthState::Up.name(), "up");
        assert_eq!(HealthState::Degraded.name(), "degraded");
        assert_eq!(HealthState::Down.name(), "down");
        assert_eq!(HealthState::Recovering.name(), "recovering");
        assert_eq!(format!("{}", HealthState::Down), "down");
    }

    #[test]
    fn mask_all_up_is_neutral() {
        let m = HealthMask::all_up(3);
        assert_eq!(m.len(), 3);
        assert!(!m.is_empty());
        assert_eq!(m.up_count(), 3);
        assert!(m.any_up());
        for d in 0..3 {
            assert!(!m.is_down(d));
            assert_eq!(m.penalty(d), 1.0);
        }
    }

    #[test]
    fn mask_tracks_states_and_penalties() {
        let mut m = HealthMask::all_up(3).with_degraded_penalty(4.0);
        m.set(0, HealthState::Down);
        m.set(1, HealthState::Degraded);
        assert!(m.is_down(0));
        assert_eq!(m.penalty(1), 4.0);
        assert_eq!(m.penalty(2), 1.0);
        assert_eq!(m.up_count(), 2);
        assert!(m.any_up());
        m.set(1, HealthState::Down);
        m.set(2, HealthState::Down);
        assert!(!m.any_up());
        assert_eq!(m.up_count(), 0);
        m.set(1, HealthState::Recovering);
        assert_eq!(m.penalty(1), 4.0);
        assert!(m.any_up());
    }

    #[test]
    #[should_panic(expected = "degraded penalty")]
    fn penalty_below_one_rejected() {
        let _ = HealthMask::all_up(1).with_degraded_penalty(0.5);
    }
}
