//! Device power model: idle floor + batch-dependent active draw.
//!
//! The paper measures power with JetPack/PyNVML; we back-derive average
//! active watts per batch size from Table 2 (energy / time) and
//! interpolate between the anchors. The Jetson sits near 5 W (rising at
//! batch 8 under memory pressure); the Ada draws 50–67 W.

use crate::util::interp;

/// Piecewise-linear active-power curve over batch size, plus idle floor.
#[derive(Debug, Clone)]
pub struct PowerModel {
    /// Draw when the device is idle (no batch in flight), watts.
    pub idle_w: f64,
    /// (batch_size, average active watts) anchors, sorted by batch.
    pub active_anchors: Vec<(f64, f64)>,
}

impl PowerModel {
    pub fn new(idle_w: f64, active_anchors: Vec<(f64, f64)>) -> Self {
        assert!(!active_anchors.is_empty(), "power model needs anchors");
        assert!(
            active_anchors.windows(2).all(|w| w[0].0 < w[1].0),
            "anchors must be sorted by batch size"
        );
        Self { idle_w, active_anchors }
    }

    /// Average draw while executing a batch of `batch_size` prompts.
    /// Never below idle (interpolation cannot dip under the floor).
    pub fn active_watts(&self, batch_size: usize) -> f64 {
        interp(&self.active_anchors, batch_size as f64).max(self.idle_w)
    }

    /// Energy for an execution of `seconds` at `batch_size`, in kWh.
    pub fn active_energy_kwh(&self, batch_size: usize, seconds: f64) -> f64 {
        self.active_watts(batch_size) * seconds / 3.6e6
    }

    /// Energy for `seconds` of idling, in kWh.
    pub fn idle_energy_kwh(&self, seconds: f64) -> f64 {
        self.idle_w * seconds / 3.6e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jetson_like() -> PowerModel {
        PowerModel::new(1.5, vec![(1.0, 4.9), (4.0, 4.7), (8.0, 10.4)])
    }

    #[test]
    fn anchors_reproduced_exactly() {
        let p = jetson_like();
        assert!((p.active_watts(1) - 4.9).abs() < 1e-12);
        assert!((p.active_watts(4) - 4.7).abs() < 1e-12);
        assert!((p.active_watts(8) - 10.4).abs() < 1e-12);
    }

    #[test]
    fn interpolates_between_anchors() {
        let p = jetson_like();
        let w6 = p.active_watts(6);
        assert!(w6 > 4.7 && w6 < 10.4);
    }

    #[test]
    fn never_below_idle() {
        // extrapolating batch=0 from the (1,4.9)-(4,4.7) segment stays >= idle
        let p = PowerModel::new(5.0, vec![(1.0, 5.1), (4.0, 20.0)]);
        assert!(p.active_watts(0) >= 5.0);
    }

    #[test]
    fn energy_arithmetic() {
        let p = jetson_like();
        // 4.9 W for 3600 s = 4.9 Wh = 0.0049 kWh
        assert!((p.active_energy_kwh(1, 3600.0) - 0.0049).abs() < 1e-12);
        assert!((p.idle_energy_kwh(3600.0) - 0.0015).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn unsorted_anchors_rejected() {
        PowerModel::new(1.0, vec![(4.0, 2.0), (1.0, 3.0)]);
    }

    #[test]
    fn paper_table2_energy_recovered() {
        // Ada b=1: 67.4 W over 3.39 s ~= 6.35e-5 kWh (Table 2)
        let ada = PowerModel::new(7.0, vec![(1.0, 67.4), (4.0, 49.9), (8.0, 61.5)]);
        let kwh = ada.active_energy_kwh(1, 3.39);
        assert!((kwh - 6.35e-5).abs() / 6.35e-5 < 0.01, "kwh={kwh}");
    }
}
