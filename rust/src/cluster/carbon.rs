//! Carbon accounting: kWh -> kgCO2e at grid intensity.
//!
//! The paper converts measured energy to carbon at a single grid
//! intensity; dividing its Table 2 carbon by energy gives ~69 gCO2e/kWh
//! on both devices (consistent with the Austrian grid). We support that
//! constant model, a diurnal profile (piecewise-linear between hourly
//! anchors), and arbitrary [`GridTrace`] time series — the general case
//! the grid subsystem forecasts and shifts against. Constant and
//! diurnal are the degenerate trace cases (one sample / 24 samples);
//! [`CarbonModel::to_trace`] performs that conversion explicitly.

use crate::grid::trace::{diurnal_shape_at, GridTrace};

/// Grid carbon intensity model.
#[derive(Debug, Clone)]
pub enum CarbonModel {
    /// Fixed intensity in gCO2e/kWh.
    Constant { g_per_kwh: f64 },
    /// 24-hour profile, `hourly[h]` = gCO2e/kWh at the top of hour h;
    /// intensity between anchors is linearly interpolated (wrapping
    /// midnight). `t` is seconds since local midnight, wrapping.
    Diurnal { hourly: [f64; 24] },
    /// An explicit intensity time series (periodic, interpolated).
    Trace(GridTrace),
}

impl CarbonModel {
    pub fn constant(g_per_kwh: f64) -> Self {
        assert!(g_per_kwh > 0.0);
        CarbonModel::Constant { g_per_kwh }
    }

    /// A plausible diurnal curve around a mean: the classic duck shape —
    /// cleanest at midday (solar), dirtiest in the evening ramp, mildly
    /// elevated overnight. `swing` is the fractional amplitude
    /// (e.g. 0.3 = ±30 %). The shape (see [`diurnal_shape_at`]) is
    /// zero-mean with max |shape| = 1, so the hourly mean equals
    /// `mean_g_per_kwh` and excursions stay within ±swing.
    pub fn diurnal(mean_g_per_kwh: f64, swing: f64) -> Self {
        assert!(mean_g_per_kwh > 0.0 && (0.0..1.0).contains(&swing));
        let mut hourly = [0.0; 24];
        for (h, slot) in hourly.iter_mut().enumerate() {
            *slot = mean_g_per_kwh * (1.0 + swing * diurnal_shape_at(h as f64));
        }
        CarbonModel::Diurnal { hourly }
    }

    /// Wrap an explicit grid trace.
    pub fn from_trace(trace: GridTrace) -> Self {
        CarbonModel::Trace(trace)
    }

    /// Intensity at simulation time `t` (seconds), gCO2e/kWh.
    pub fn intensity_at(&self, t: f64) -> f64 {
        match self {
            CarbonModel::Constant { g_per_kwh } => *g_per_kwh,
            CarbonModel::Diurnal { hourly } => {
                let h = t.rem_euclid(86_400.0) / 3600.0;
                let i = (h.floor() as usize) % 24;
                let frac = h - h.floor();
                let a = hourly[i];
                let b = hourly[(i + 1) % 24];
                a + (b - a) * frac
            }
            CarbonModel::Trace(trace) => trace.intensity_at(t),
        }
    }

    /// Emissions for `kwh` of energy consumed at time `t`, in kgCO2e.
    pub fn kg_co2e(&self, kwh: f64, t: f64) -> f64 {
        kwh * self.intensity_at(t) / 1000.0
    }

    /// Flatten any model into an explicit trace sampled at `step_s`
    /// over one day (constant models collapse to a single sample) —
    /// the degenerate-case absorption the grid subsystem builds on.
    pub fn to_trace(&self, step_s: f64) -> GridTrace {
        match self {
            CarbonModel::Constant { g_per_kwh } => GridTrace::constant(*g_per_kwh),
            CarbonModel::Diurnal { .. } => {
                assert!(step_s > 0.0);
                let n = ((86_400.0 / step_s).round() as usize).max(1);
                GridTrace::from_fn("diurnal", step_s, n, |t| self.intensity_at(t))
            }
            CarbonModel::Trace(trace) => trace.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_matches_paper_conversion() {
        // Table 2, Ada b=1: 6.35e-5 kWh -> 4.38e-6 kgCO2e at 69 g/kWh
        let m = CarbonModel::constant(69.0);
        let kg = m.kg_co2e(6.35e-5, 0.0);
        assert!((kg - 4.38e-6).abs() / 4.38e-6 < 0.01, "kg={kg}");
    }

    #[test]
    fn constant_time_invariant() {
        let m = CarbonModel::constant(100.0);
        assert_eq!(m.intensity_at(0.0), m.intensity_at(1e6));
    }

    #[test]
    fn diurnal_mean_and_swing() {
        let m = CarbonModel::diurnal(69.0, 0.3);
        let vals: Vec<f64> = (0..24).map(|h| m.intensity_at(h as f64 * 3600.0)).collect();
        let mean = vals.iter().sum::<f64>() / 24.0;
        assert!((mean - 69.0).abs() / 69.0 < 0.05, "mean={mean}");
        let max = vals.iter().cloned().fold(f64::MIN, f64::max);
        let min = vals.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max <= 69.0 * 1.32 && min >= 69.0 * 0.68);
        assert!(max > min, "profile must vary");
        // duck shape: solar midday cleaner than the evening ramp
        assert!(m.intensity_at(13.0 * 3600.0) < m.intensity_at(19.0 * 3600.0));
        assert!(m.intensity_at(13.0 * 3600.0) < m.intensity_at(3.0 * 3600.0));
    }

    #[test]
    fn diurnal_wraps_across_days() {
        let m = CarbonModel::diurnal(50.0, 0.2);
        assert_eq!(m.intensity_at(3600.0), m.intensity_at(3600.0 + 86_400.0));
        assert_eq!(m.intensity_at(-3600.0), m.intensity_at(82_800.0));
    }

    #[test]
    fn diurnal_interpolates_between_hourly_anchors() {
        let m = CarbonModel::diurnal(69.0, 0.3);
        let CarbonModel::Diurnal { hourly } = m.clone() else { unreachable!() };
        // anchor values are hit exactly at the top of each hour
        for (h, &v) in hourly.iter().enumerate() {
            assert!((m.intensity_at(h as f64 * 3600.0) - v).abs() < 1e-12, "hour {h}");
        }
        // half past sits midway between neighbouring anchors
        let mid = m.intensity_at(17.5 * 3600.0);
        assert!((mid - 0.5 * (hourly[17] + hourly[18])).abs() < 1e-9);
        // no step discontinuities: fine steps move intensity smoothly
        let mut prev = m.intensity_at(0.0);
        for k in 1..(24 * 60) {
            let cur = m.intensity_at(k as f64 * 60.0);
            let max_hourly_gap = 69.0 * 0.3 * 2.05; // largest anchor-to-anchor move
            assert!(
                (cur - prev).abs() <= max_hourly_gap / 60.0 + 1e-9,
                "jump at minute {k}: {prev} -> {cur}"
            );
            prev = cur;
        }
        // ... including across midnight
        let before = m.intensity_at(86_399.0);
        let after = m.intensity_at(86_401.0);
        assert!((before - after).abs() < 0.1, "{before} vs {after}");
    }

    #[test]
    fn trace_model_follows_its_trace() {
        let trace = GridTrace::new("t", 1800.0, vec![50.0, 100.0, 75.0, 60.0]);
        let m = CarbonModel::from_trace(trace.clone());
        for k in 0..8 {
            let t = k as f64 * 450.0;
            assert_eq!(m.intensity_at(t), trace.intensity_at(t));
        }
        assert!((m.kg_co2e(1.0, 0.0) - 0.05).abs() < 1e-12);
    }

    #[test]
    fn to_trace_absorbs_constant_and_diurnal() {
        let c = CarbonModel::constant(80.0).to_trace(900.0);
        assert_eq!(c.len(), 1);
        assert_eq!(c.intensity_at(12345.0), 80.0);

        let m = CarbonModel::diurnal(69.0, 0.3);
        let t = m.to_trace(3600.0);
        assert_eq!(t.len(), 24);
        for h in 0..24 {
            let at = h as f64 * 3600.0;
            assert!((t.intensity_at(at) - m.intensity_at(at)).abs() < 1e-12, "hour {h}");
        }
    }

    #[test]
    #[should_panic]
    fn non_positive_intensity_rejected() {
        CarbonModel::constant(0.0);
    }
}
