//! Carbon accounting: kWh -> kgCO2e at grid intensity.
//!
//! The paper converts measured energy to carbon at a single grid
//! intensity; dividing its Table 2 carbon by energy gives ~69 gCO2e/kWh
//! on both devices (consistent with the Austrian grid). We support that
//! constant model plus a diurnal profile used by the carbon-cap
//! extension example (route more aggressively to the efficient device
//! when the grid is dirty).

/// Grid carbon intensity model.
#[derive(Debug, Clone)]
pub enum CarbonModel {
    /// Fixed intensity in gCO2e/kWh.
    Constant { g_per_kwh: f64 },
    /// 24-hour piecewise profile, `hourly[h]` = gCO2e/kWh during hour h.
    /// `t` is interpreted as seconds since local midnight, wrapping.
    Diurnal { hourly: [f64; 24] },
}

impl CarbonModel {
    pub fn constant(g_per_kwh: f64) -> Self {
        assert!(g_per_kwh > 0.0);
        CarbonModel::Constant { g_per_kwh }
    }

    /// A plausible diurnal curve around a mean: the classic duck shape —
    /// cleanest at midday (solar), dirtiest in the evening ramp, mildly
    /// elevated overnight. `swing` is the fractional amplitude
    /// (e.g. 0.3 = ±30 %). The shape vector below is zero-mean with
    /// max |shape| = 1, so the hourly mean equals `mean_g_per_kwh` and
    /// excursions stay within ±swing.
    pub fn diurnal(mean_g_per_kwh: f64, swing: f64) -> Self {
        assert!(mean_g_per_kwh > 0.0 && (0.0..1.0).contains(&swing));
        // hours 0..23; trough 12-15, peak 18-21
        const SHAPE: [f64; 24] = [
            0.35, 0.30, 0.25, 0.20, 0.15, 0.10, 0.00, -0.20, //  0- 7
            -0.40, -0.60, -0.80, -0.95, -1.00, -1.00, -0.90, -0.70, //  8-15
            -0.20, 0.40, 0.85, 1.00, 0.95, 0.80, 0.60, 0.45, // 16-23
        ];
        let mean_shape: f64 = SHAPE.iter().sum::<f64>() / 24.0;
        let mut hourly = [0.0; 24];
        for (h, slot) in hourly.iter_mut().enumerate() {
            *slot = mean_g_per_kwh * (1.0 + swing * (SHAPE[h] - mean_shape));
        }
        CarbonModel::Diurnal { hourly }
    }

    /// Intensity at simulation time `t` (seconds), gCO2e/kWh.
    pub fn intensity_at(&self, t: f64) -> f64 {
        match self {
            CarbonModel::Constant { g_per_kwh } => *g_per_kwh,
            CarbonModel::Diurnal { hourly } => {
                let sec = t.rem_euclid(86_400.0);
                hourly[(sec / 3600.0) as usize % 24]
            }
        }
    }

    /// Emissions for `kwh` of energy consumed at time `t`, in kgCO2e.
    pub fn kg_co2e(&self, kwh: f64, t: f64) -> f64 {
        kwh * self.intensity_at(t) / 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_matches_paper_conversion() {
        // Table 2, Ada b=1: 6.35e-5 kWh -> 4.38e-6 kgCO2e at 69 g/kWh
        let m = CarbonModel::constant(69.0);
        let kg = m.kg_co2e(6.35e-5, 0.0);
        assert!((kg - 4.38e-6).abs() / 4.38e-6 < 0.01, "kg={kg}");
    }

    #[test]
    fn constant_time_invariant() {
        let m = CarbonModel::constant(100.0);
        assert_eq!(m.intensity_at(0.0), m.intensity_at(1e6));
    }

    #[test]
    fn diurnal_mean_and_swing() {
        let m = CarbonModel::diurnal(69.0, 0.3);
        let vals: Vec<f64> = (0..24).map(|h| m.intensity_at(h as f64 * 3600.0)).collect();
        let mean = vals.iter().sum::<f64>() / 24.0;
        assert!((mean - 69.0).abs() / 69.0 < 0.05, "mean={mean}");
        let max = vals.iter().cloned().fold(f64::MIN, f64::max);
        let min = vals.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max <= 69.0 * 1.32 && min >= 69.0 * 0.68);
        assert!(max > min, "profile must vary");
        // duck shape: solar midday cleaner than the evening ramp
        assert!(m.intensity_at(13.0 * 3600.0) < m.intensity_at(19.0 * 3600.0));
        assert!(m.intensity_at(13.0 * 3600.0) < m.intensity_at(3.0 * 3600.0));
    }

    #[test]
    fn diurnal_wraps_across_days() {
        let m = CarbonModel::diurnal(50.0, 0.2);
        assert_eq!(m.intensity_at(3600.0), m.intensity_at(3600.0 + 86_400.0));
        assert_eq!(m.intensity_at(-3600.0), m.intensity_at(82_800.0));
    }

    #[test]
    #[should_panic]
    fn non_positive_intensity_rejected() {
        CarbonModel::constant(0.0);
    }
}
