//! Device profiles: one per cluster member, combining identity, memory,
//! power and latency calibration.

use crate::config::{DeviceConfig, DeviceKind};
use crate::simulator::calibration::{self, DeviceCalibration, LatencyCalibration};

use super::{MemoryModel, PowerModel};

/// A fully-instantiated device: everything the scheduler, simulator and
/// cost estimator need to know about one cluster member.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: String,
    pub kind: DeviceKind,
    /// Artifact variant this device serves (manifest key, e.g.
    /// "edge-1b-sim" on the Jetson).
    pub model: String,
    pub memory: MemoryModel,
    pub power: PowerModel,
    pub latency: LatencyCalibration,
    pub saturation: calibration::SaturationCalibration,
    /// Median output tokens for this device's model (drives sampled
    /// generation lengths in calibrated mode).
    pub output_median_tokens: f64,
}

impl DeviceProfile {
    /// Build from config + the Table-2 calibration for its kind.
    pub fn from_config(cfg: &DeviceConfig) -> Self {
        let cal = calibration::for_kind(cfg.kind);
        Self::from_calibration(cfg.name.clone(), cfg.kind, cfg.model.clone(), cfg.gpu_mem_gb, cal)
    }

    /// Build from an explicit calibration bundle (tests, ablations).
    pub fn from_calibration(
        name: String,
        kind: DeviceKind,
        model: String,
        gpu_mem_gb: f64,
        cal: DeviceCalibration,
    ) -> Self {
        DeviceProfile {
            name,
            kind,
            model,
            memory: MemoryModel {
                capacity_gb: gpu_mem_gb,
                weights_gb: cal.weights_gb,
                kv_mb_per_token: cal.kv_mb_per_token,
                activation_mb_per_seq: cal.activation_mb_per_seq,
                saturation_start: cal.saturation_start,
            },
            power: PowerModel::new(cal.idle_w, cal.power_anchors),
            latency: cal.latency,
            saturation: cal.saturation,
            output_median_tokens: cal.output_median_tokens,
        }
    }

    /// Convenience: the paper's Jetson Orin NX 8 GB profile.
    pub fn jetson() -> Self {
        Self::from_config(&DeviceConfig {
            name: "jetson-orin-nx".into(),
            kind: DeviceKind::Jetson,
            gpu_mem_gb: 8.0,
            model: "edge-1b-sim".into(),
        })
    }

    /// Convenience: the paper's NVIDIA Ada 2000 16 GB profile.
    pub fn ada() -> Self {
        Self::from_config(&DeviceConfig {
            name: "ada-2000".into(),
            kind: DeviceKind::Ada,
            gpu_mem_gb: 16.0,
            model: "edge-12b-sim".into(),
        })
    }

    /// Convenience: the cloud API point behind the cluster's link.
    pub fn cloud() -> Self {
        Self::from_config(&DeviceConfig {
            name: "gemini-flash".into(),
            kind: DeviceKind::Cloud,
            gpu_mem_gb: 80.0,
            model: "edge-12b-sim".into(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_profiles_have_expected_identity() {
        let j = DeviceProfile::jetson();
        assert_eq!(j.kind, DeviceKind::Jetson);
        assert_eq!(j.memory.capacity_gb, 8.0);
        assert_eq!(j.model, "edge-1b-sim");

        let a = DeviceProfile::ada();
        assert_eq!(a.memory.capacity_gb, 16.0);
        assert_eq!(a.model, "edge-12b-sim");
    }

    #[test]
    fn jetson_saturates_before_ada_on_batch8() {
        let j = DeviceProfile::jetson();
        let a = DeviceProfile::ada();
        // 8 × 1024-token sequences: over capacity on the Jetson,
        // tight-but-ok on the Ada (the paper's batch-8 finding)
        assert!(j.memory.utilization(8, 1024) > 1.0);
        assert!(a.memory.utilization(8, 1024) <= 1.05);
        assert!(j.memory.saturation(8, 1024) > a.memory.saturation(8, 1024));
    }

    #[test]
    fn power_hierarchy_matches_paper() {
        let j = DeviceProfile::jetson();
        let a = DeviceProfile::ada();
        for b in [1, 4, 8] {
            assert!(j.power.active_watts(b) < a.power.active_watts(b));
        }
    }
}
