//! Fig. 1 reproduction: inference performance of P1–P4 across
//! Jetson-1B, Ada-12B and the cloud API.
//!
//! The paper's figure plots IT (inference time), TTFT, TPS and TPOT for
//! the four Table-1 prompts on the three backends. We run each prompt
//! at batch 1 through the calibrated simulator (cloud requests pay the
//! network link) and emit one row per (prompt, backend).
//!
//! Shape expectations (paper §2): the 12B Ada has the shortest TTFT but
//! higher IT/TPOT on long generations; the cloud wins IT/TPS on complex
//! prompts (P1, P2) but loses on short factual ones (P4) to dispatch +
//! bandwidth overhead.

use crate::cluster::DeviceProfile;
use crate::config::DeviceKind;
use crate::report::{fmt, Table};
use crate::simulator::{simulate_batch, BatchWork};
use crate::workload::canonical;

/// One measured cell of the figure.
#[derive(Debug, Clone)]
pub struct Fig1Point {
    pub prompt: &'static str,
    pub backend: String,
    pub it_s: f64,
    pub ttft_s: f64,
    pub tps: f64,
    pub tpot_s: f64,
}

/// Run the experiment and return (points, rendered table).
pub fn run() -> (Vec<Fig1Point>, Table) {
    let backends = [DeviceProfile::jetson(), DeviceProfile::ada(), DeviceProfile::cloud()];
    let link = crate::cluster::LinkModel::new(80.0, 50.0);

    let mut points = Vec::new();
    for p in canonical::ALL {
        for dev in &backends {
            let out = p.to_prompt(0).output_tokens_on(dev.output_median_tokens);
            let work = BatchWork::new(vec![p.text.len()], vec![out]);
            let t = simulate_batch(dev, &work, None);
            let net = if dev.kind == DeviceKind::Cloud {
                link.token_round_trip_s(p.text.len(), out)
            } else {
                0.0
            };
            let it = t.total_s + net;
            points.push(Fig1Point {
                prompt: p.id,
                backend: dev.name.clone(),
                it_s: it,
                ttft_s: t.ttft_s + net * 0.5,
                tps: out as f64 / it,
                tpot_s: t.decode_s / out.max(1) as f64,
            });
        }
    }

    let mut table = Table::new(
        "fig1",
        "Fig. 1 — inference performance, P1-P4 x {Jetson 1B, Ada 12B, cloud}",
        &["prompt", "backend", "IT (s)", "TTFT (s)", "TPS (tok/s)", "TPOT (s)"],
    );
    for pt in &points {
        table.row(vec![
            pt.prompt.to_string(),
            pt.backend.clone(),
            fmt::secs(pt.it_s),
            fmt::secs(pt.ttft_s),
            fmt::f2(pt.tps),
            format!("{:.3}", pt.tpot_s),
        ]);
    }
    table.note("batch size 1; cloud rows include the 80ms-RTT/50Mbps link");
    (points, table)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point<'a>(pts: &'a [Fig1Point], prompt: &str, backend: &str) -> &'a Fig1Point {
        pts.iter()
            .find(|p| p.prompt == prompt && p.backend.contains(backend))
            .unwrap()
    }

    #[test]
    fn shape_matches_paper_figure() {
        let (pts, _) = run();
        assert_eq!(pts.len(), 12);

        // Ada has the shortest TTFT among edge devices on every prompt
        for p in ["P1", "P2", "P3", "P4"] {
            let ada = point(&pts, p, "ada");
            let jet = point(&pts, p, "jetson");
            assert!(ada.ttft_s < jet.ttft_s, "{p}");
        }
        // cloud wins IT on the complex prompts...
        for p in ["P1", "P2"] {
            let cloud = point(&pts, p, "gemini");
            let jet = point(&pts, p, "jetson");
            assert!(cloud.it_s < jet.it_s, "{p}");
        }
        // ...but loses to the edge on the trivial factual P4
        let cloud = point(&pts, "P4", "gemini");
        let ada = point(&pts, "P4", "ada");
        assert!(cloud.ttft_s > ada.ttft_s, "cloud dispatch overhead must dominate P4");

        // cloud decode is the fastest (Gemini-Flash class TPOT)
        for p in ["P1", "P2", "P3", "P4"] {
            let c = point(&pts, p, "gemini");
            let j = point(&pts, p, "jetson");
            assert!(c.tpot_s < j.tpot_s);
        }
    }

    #[test]
    fn table_renders_all_rows() {
        let (_, table) = run();
        assert_eq!(table.rows.len(), 12);
        let ascii = table.ascii();
        assert!(ascii.contains("P1") && ascii.contains("P4"));
    }
}
