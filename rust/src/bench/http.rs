//! HTTP fast-path load harness: `verdant bench http`.
//!
//! Drives the real network stack — [`crate::server::http`] over a
//! loopback socket with the stub backend — through a
//! {connections} × {keep-alive, close} × {streaming, unary} sweep and
//! reports req/s, latency percentiles, allocations per request and
//! sheds per combo. `--json` writes `BENCH_http.json`, keyed like
//! `BENCH_scale.json` (Plane/Strategy/Prompts/Threads), which
//! `ci/bench_gate.py` gates against `BENCH_http_baseline.json`
//! (keep-alive rows only; close rows are the comparison baseline the
//! keep-alive ≥ 2× unary claim is checked against).
//!
//! Each combo binds a fresh server on an ephemeral port, fires
//! [`REQUESTS_PER_COMBO`] requests from `connections` client threads,
//! then drains via `POST /admin/drain` and folds the server's own
//! [`ServeReport`] shed count into the row. The stub occupancy sleeps
//! vanish at [`BENCH_TIME_SCALE`] compression, so the rows time the
//! network path — parse, route, queue handoff, format, write — not the
//! simulated inference.
//!
//! Allocations/request is a process-wide delta of
//! [`crate::util::alloc::allocation_count`] across the combo (counted
//! only under the `verdant` binary, whose `#[global_allocator]` is the
//! counting wrapper; zero when the wrapper is not registered). The
//! figure includes the client threads' own buffers, so it is an upper
//! bound on the server-side pressure — useful as a trajectory, not an
//! absolute.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Instant;

use crate::cluster::Cluster;
use crate::report::{fmt, Table};
use crate::server::{HttpOptions, HttpServer, ServeOptions};
use crate::util::alloc::allocation_count;
use crate::util::stats::Histogram;

use super::Env;

/// Client connection counts swept per strategy.
pub const CONNS: [usize; 3] = [1, 8, 64];

/// Requests fired per combo (split across the combo's connections).
pub const REQUESTS_PER_COMBO: usize = 256;

/// Virtual-seconds-per-wallclock-second compression: high enough that
/// every stub occupancy sleep rounds to zero and the sweep times only
/// the network path.
pub const BENCH_TIME_SCALE: f64 = 1_000_000.0;

/// Tokens generated per request — small and fixed so the SSE rows
/// stream a deterministic frame count.
pub const BENCH_MAX_TOKENS: usize = 4;

/// One measured combo.
#[derive(Debug, Clone)]
pub struct HttpRow {
    /// Always `"http"` — the gate key's plane column.
    pub plane: &'static str,
    /// `"keep-alive unary"`, `"keep-alive streaming"`, `"close
    /// unary"`, `"close streaming"`.
    pub strategy: String,
    /// Requests fired (the gate key's Prompts column).
    pub prompts: usize,
    /// Client connections (the gate key's Threads column).
    pub threads: usize,
    pub wall_s: f64,
    pub req_per_s: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Process-wide allocation delta / requests (see module doc).
    pub allocs_per_req: f64,
    /// Sheds the server reported for the combo (must be 0 at the
    /// default pool — the CI sanity step hard-fails otherwise).
    pub shed: usize,
}

/// Full sweep at the standard sizes.
pub fn run(env: &Env) -> (Vec<HttpRow>, Table) {
    run_with(env, &CONNS, REQUESTS_PER_COMBO)
}

/// Parameterized sweep (tests shrink it).
pub fn run_with(env: &Env, conns: &[usize], requests: usize) -> (Vec<HttpRow>, Table) {
    let cluster = Cluster::from_config(&env.cfg.cluster);
    let db = std::sync::Arc::new(env.db.clone());
    let mut rows = Vec::new();
    for &c in conns {
        for keep in [true, false] {
            for streaming in [false, true] {
                rows.push(run_combo(&cluster, &db, c, keep, streaming, requests));
            }
        }
    }

    let mut table = Table::new(
        "BENCH_http",
        "HTTP fast path — req/s by connections × keep-alive × streaming (loopback, stub)",
        &["Plane", "Strategy", "Prompts", "Threads", "Wall (s)", "Req/s", "p50 (ms)",
          "p95 (ms)", "p99 (ms)", "Allocs/req", "Shed"],
    );
    for r in &rows {
        table.row(vec![
            r.plane.to_string(),
            r.strategy.clone(),
            r.prompts.to_string(),
            r.threads.to_string(),
            fmt::secs(r.wall_s),
            format!("{:.0}", r.req_per_s),
            format!("{:.3}", r.p50_ms),
            format!("{:.3}", r.p95_ms),
            format!("{:.3}", r.p99_ms),
            format!("{:.1}", r.allocs_per_req),
            r.shed.to_string(),
        ]);
    }
    table.note(format!(
        "{requests} requests per combo over loopback, stub backend at {BENCH_TIME_SCALE:.0}x \
         time compression ({BENCH_MAX_TOKENS} tokens per completion, batch 1, default \
         connection pool); Threads = client connections; keep-alive unary reuses one \
         socket per thread, close opens one per request, streaming reads the SSE frames \
         to [DONE] (an SSE stream always terminates its connection, so its keep-alive \
         and close rows differ only in the request header); allocs/req is the \
         process-wide allocation-counter delta / requests — client buffers included, \
         so an upper bound on server-side pressure (0 when the counting allocator \
         is not registered, i.e. outside the verdant binary); the CI gate holds the \
         keep-alive rows' req/s within 25% of BENCH_http_baseline.json"
    ));
    (rows, table)
}

/// Bind a fresh server, fire `requests` across `conns` client threads,
/// drain, and fold the server's report into one row.
fn run_combo(
    cluster: &Cluster,
    db: &std::sync::Arc<crate::coordinator::BenchmarkDb>,
    conns: usize,
    keep: bool,
    streaming: bool,
    requests: usize,
) -> HttpRow {
    let opts = ServeOptions::builder()
        .cluster(cluster)
        .batch_size(1)
        .batch_timeout(std::time::Duration::from_millis(1))
        .max_new_tokens(BENCH_MAX_TOKENS)
        .time_scale(BENCH_TIME_SCALE)
        .strategy("latency-aware")
        .execution(crate::config::ExecutionMode::Stub)
        .db(Some(std::sync::Arc::clone(db)))
        .build()
        .expect("bench serve options validate");
    let http = HttpOptions { addr: "127.0.0.1:0".into(), ..HttpOptions::default() };
    let server = HttpServer::bind(cluster, &opts, &http).expect("bench server binds");
    let addr = server.local_addr().expect("bound address");
    let server = std::thread::spawn(move || server.run());

    let body = format!(
        "{{\"messages\":[{{\"role\":\"user\",\"content\":\"bench\"}}],\
         \"stream\":{streaming},\"max_tokens\":{BENCH_MAX_TOKENS}}}"
    );
    let request = format!(
        "POST /v1/chat/completions HTTP/1.1\r\nHost: bench\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n{}",
        body.len(),
        if keep { "keep-alive" } else { "close" },
        body
    );
    let per_thread = requests.div_ceil(conns);
    let total = per_thread * conns;

    let allocs_before = allocation_count();
    let t0 = Instant::now();
    let mut clients = Vec::new();
    for _ in 0..conns {
        let request = request.clone();
        clients.push(std::thread::spawn(move || -> Vec<f64> {
            let mut lat = Vec::with_capacity(per_thread);
            let mut buf: Vec<u8> = Vec::with_capacity(8192);
            // keep-alive unary rides one socket for the whole thread;
            // everything else (close, and every SSE stream — the
            // server ends those connections) reconnects per request
            let reuse = keep && !streaming;
            let mut conn: Option<TcpStream> = None;
            for _ in 0..per_thread {
                let r0 = Instant::now();
                if conn.is_none() {
                    conn = Some(connect_retry(addr));
                }
                let s = conn.as_mut().expect("client connected");
                s.write_all(request.as_bytes()).expect("bench request write");
                buf.clear();
                if reuse {
                    read_framed(s, &mut buf);
                } else {
                    s.read_to_end(&mut buf).expect("bench response read");
                    conn = None;
                }
                assert!(
                    buf.starts_with(b"HTTP/1.1 200"),
                    "bench request failed: {}",
                    String::from_utf8_lossy(&buf[..buf.len().min(120)])
                );
                if streaming {
                    assert!(
                        buf.windows(13).any(|w| w == b"data: [DONE]\n"),
                        "SSE stream did not finish"
                    );
                }
                lat.push(r0.elapsed().as_secs_f64());
            }
            lat
        }));
    }
    let mut hist = Histogram::latency();
    for c in clients {
        for l in c.join().expect("bench client thread") {
            hist.add(l);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let allocs = allocation_count().saturating_sub(allocs_before);

    // drain and collect the server's own accounting
    let mut s = connect_retry(addr);
    s.write_all(b"POST /admin/drain HTTP/1.1\r\nContent-Length: 0\r\n\r\n")
        .expect("drain write");
    let mut sink = Vec::new();
    let _ = s.read_to_end(&mut sink);
    let report = server.join().expect("server thread").expect("server run");
    assert_eq!(report.completed, total, "bench dropped requests");

    HttpRow {
        plane: "http",
        strategy: format!(
            "{} {}",
            if keep { "keep-alive" } else { "close" },
            if streaming { "streaming" } else { "unary" }
        ),
        prompts: total,
        threads: conns,
        wall_s: wall,
        req_per_s: total as f64 / wall.max(1e-9),
        p50_ms: hist.p50() * 1000.0,
        p95_ms: hist.p95() * 1000.0,
        p99_ms: hist.p99() * 1000.0,
        allocs_per_req: allocs as f64 / total as f64,
        shed: report.shed,
    }
}

/// Connect with a short retry loop — the accept thread polls at 5 ms,
/// and a SYN burst right at bind time can race the first poll.
fn connect_retry(addr: std::net::SocketAddr) -> TcpStream {
    for _ in 0..200 {
        if let Ok(s) = TcpStream::connect(addr) {
            s.set_nodelay(true).expect("nodelay");
            return s;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    panic!("bench client could not connect to {addr}");
}

/// Read exactly one `Content-Length`-framed response from a kept-alive
/// socket into `buf`.
fn read_framed(s: &mut TcpStream, buf: &mut Vec<u8>) {
    let mut tmp = [0u8; 4096];
    let header_end = loop {
        if let Some(pos) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break pos + 4;
        }
        let n = s.read(&mut tmp).expect("bench header read");
        assert!(n > 0, "connection closed mid-headers");
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..header_end]);
    let cl: usize = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.eq_ignore_ascii_case("content-length").then(|| v.trim().parse().ok())?
        })
        .expect("framed response has Content-Length");
    while buf.len() < header_end + cl {
        let n = s.read(&mut tmp).expect("bench body read");
        assert!(n > 0, "connection closed mid-body");
        buf.extend_from_slice(&tmp[..n]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_sweep_completes_with_zero_shed() {
        let env = Env::small(4);
        let (rows, table) = run_with(&env, &[2], 8);
        assert_eq!(rows.len(), 4, "2 strategies x 2 modes at one connection count");
        for r in &rows {
            assert_eq!(r.plane, "http");
            assert_eq!(r.prompts, 8);
            assert_eq!(r.threads, 2);
            assert_eq!(r.shed, 0, "{}: default pool must not shed", r.strategy);
            assert!(r.req_per_s > 0.0, "{}: throughput measured", r.strategy);
            // library tests run without the counting allocator
            assert_eq!(r.allocs_per_req, 0.0);
        }
        assert_eq!(table.rows.len(), 4);
    }
}
