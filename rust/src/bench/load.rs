//! Open-loop load sweep (serving extension; paper future work).
//!
//! Sweeps the offered arrival rate and reports steady-state latency
//! (mean/p50/p95), utilization and batch fill per routing strategy and
//! batching policy — the latency-vs-load curve a deployment would use
//! to size this cluster.

use crate::config::{Arrival, ExperimentConfig};
use crate::coordinator::online::{run_online, BatchPolicy, OnlineConfig};
use crate::report::{fmt, Table};
use crate::workload::{trace, Corpus};

use super::Env;

/// Offered loads (requests/second).
pub const RATES: [f64; 5] = [0.05, 0.1, 0.2, 0.5, 1.0];

/// One sweep point.
#[derive(Debug, Clone)]
pub struct LoadRow {
    pub strategy: String,
    pub policy: &'static str,
    pub rate: f64,
    pub latency_mean_s: f64,
    pub latency_p95_s: f64,
    pub mean_fill: f64,
    pub max_utilization: f64,
}

/// Run the sweep and return (rows, rendered table).
pub fn run(env: &Env) -> (Vec<LoadRow>, Table) {
    let mut rows = Vec::new();
    let base: ExperimentConfig = env.cfg.clone();

    for (strategy, policy, label) in [
        ("latency-aware", BatchPolicy::Immediate, "immediate"),
        ("latency-aware", BatchPolicy::WaitFill { timeout_s: 10.0 }, "wait-fill@10s"),
        ("round-robin", BatchPolicy::Immediate, "immediate"),
    ] {
        for &rate in &RATES {
            let mut corpus = Corpus::generate(&base.workload);
            trace::assign_arrivals(&mut corpus.prompts, Arrival::Open { rate }, base.workload.seed);
            let cfg = OnlineConfig {
                batch_size: base.serving.batch_size,
                policy,
                strategy: strategy.into(),
                grid: None,
                ..OnlineConfig::default()
            };
            let r = run_online(&env.cluster, &corpus.prompts, &env.db, &cfg)
                .expect("bench strategies resolve");
            rows.push(LoadRow {
                strategy: strategy.into(),
                policy: label,
                rate,
                latency_mean_s: r.latency.mean(),
                latency_p95_s: r.latency_hist.p95(),
                mean_fill: r.batch_fill.mean(),
                max_utilization: r
                    .utilization
                    .iter()
                    .map(|(_, u)| *u)
                    .fold(0.0, f64::max),
            });
        }
    }

    let mut table = Table::new(
        "load",
        "Open-loop load sweep — latency vs offered rate (batch 4)",
        &["Strategy", "Policy", "Rate (req/s)", "Lat mean (s)", "Lat p95 (s)", "Fill", "Max util"],
    );
    for r in &rows {
        table.row(vec![
            r.strategy.clone(),
            r.policy.to_string(),
            format!("{:.2}", r.rate),
            fmt::secs(r.latency_mean_s),
            fmt::secs(r.latency_p95_s),
            format!("{:.2}", r.mean_fill),
            fmt::pct(r.max_utilization),
        ]);
    }
    table.note("virtual-time DES over the calibrated devices; 500-prompt trace per point");
    (rows, table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_monotone_in_offered_load() {
        let env = Env::small(150);
        let (rows, table) = run(&env);
        assert_eq!(rows.len(), 15);
        assert_eq!(table.rows.len(), 15);
        let la: Vec<&LoadRow> = rows
            .iter()
            .filter(|r| r.strategy == "latency-aware" && r.policy == "immediate")
            .collect();
        assert!(la.last().unwrap().latency_mean_s > la.first().unwrap().latency_mean_s);
        // utilization rises with load
        assert!(la.last().unwrap().max_utilization > la.first().unwrap().max_utilization);
    }

    #[test]
    fn waitfill_fills_batches_better_at_low_load() {
        let env = Env::small(150);
        let (rows, _) = run(&env);
        let find = |policy: &str, rate: f64| {
            rows.iter()
                .find(|r| r.strategy == "latency-aware" && r.policy == policy && r.rate == rate)
                .unwrap()
        };
        assert!(find("wait-fill@10s", 0.2).mean_fill >= find("immediate", 0.2).mean_fill);
    }
}
