//! Micro-benchmark harness (criterion substitute, offline build).
//!
//! Wallclock timing with warmup, fixed iteration counts and summary
//! statistics. Used by `rust/benches/*.rs` (harness = false binaries)
//! for the L3 hot-path measurements recorded in EXPERIMENTS.md §Perf.

use crate::util::stats::Summary;
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchResult {
    /// criterion-ish one-liner.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12} / iter  (min {:>12}, max {:>12}, n={})",
            self.name,
            human_time(self.mean_s),
            human_time(self.min_s),
            human_time(self.max_s),
            self.iters
        )
    }
}

/// Pretty-print a duration in s/ms/µs/ns.
pub fn human_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
/// The closure's return value is black-boxed to keep the optimizer
/// honest.
pub fn bench<T>(name: &str, warmup: u64, iters: u64, mut f: impl FnMut() -> T) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut stats = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        stats.add(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: stats.mean(),
        std_s: stats.std(),
        min_s: stats.min(),
        max_s: stats.max(),
    }
}

/// Run and print a group of benches with a header.
pub fn group(title: &str) {
    println!("\n### {title}");
}

/// Print one result.
pub fn report(r: &BenchResult) {
    println!("{}", r.line());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 2, 10, || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert_eq!(r.iters, 10);
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.mean_s && r.mean_s <= r.max_s);
    }

    #[test]
    fn human_time_units() {
        assert_eq!(human_time(2.5), "2.500 s");
        assert_eq!(human_time(2.5e-3), "2.500 ms");
        assert_eq!(human_time(2.5e-6), "2.500 µs");
        assert_eq!(human_time(2.5e-9), "2.5 ns");
    }
}
