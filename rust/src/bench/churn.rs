//! Availability benchmark: `verdant bench churn`.
//!
//! Sweeps routing strategies × device-availability scenarios through
//! the open-loop DES and reports what failover buys: completions,
//! shed work, migrations and the carbon/latency price of each
//! scenario. The scenarios:
//!
//! - **always-up** — no churn; the bit-for-bit baseline every other
//!   row is compared against.
//! - **cleanest-down** — the cleanest device (the paper's Jetson)
//!   drops out shortly after the run starts and stays down; failover
//!   re-homes its queue and killed in-flight batches onto survivors.
//!   The row the issue cares about: forecast-carbon-aware must keep
//!   serving (zero shed) when its favourite device disappears.
//! - **cleanest-down-nofail** — the same outage with failover
//!   disabled: disrupted work is shed instead of migrated. The
//!   contrast row that prices the failover machinery.
//! - **flaky** — a seeded stochastic MTBF/MTTR schedule across the
//!   whole cluster (intermittent churn rather than one clean loss).
//!
//! Every row preserves conservation: `completed + shed` equals the
//! corpus size — churn may degrade service, never lose work silently.

use crate::coordinator::online::{run_online, OnlineConfig};
use crate::report::{fmt, Table};
use crate::simulator::{ChurnSchedule, OutageWindow};
use crate::util::rng::Rng;

use super::Env;

/// Strategies compared across availability scenarios: the paper's
/// Table 3 set plus the forecast router (the one with the strongest
/// preference for the clean device, hence the most to lose).
pub const STRATEGIES: [&str; 5] = [
    "all-on-jetson-orin-nx",
    "all-on-ada-2000",
    "carbon-aware",
    "latency-aware",
    "forecast-carbon-aware",
];

/// Outage start for the scripted scenarios, virtual seconds. Late
/// enough that work is queued (closed arrivals land at t=0), early
/// enough that almost everything is still disrupted.
pub const OUTAGE_START_S: f64 = 1.0;

/// One strategy × scenario run.
#[derive(Debug, Clone)]
pub struct ChurnRow {
    pub strategy: String,
    pub scenario: &'static str,
    pub completed: usize,
    /// Prompts shed (counted, never silently lost).
    pub shed: usize,
    /// In-flight batch members migrated off a failed device.
    pub failovers: u64,
    /// Queued prompts re-homed when their device went down.
    pub requeues: u64,
    pub outages: u64,
    pub carbon_kg: f64,
    pub latency_mean_s: f64,
    pub deadline_violations: usize,
}

struct Scenario {
    name: &'static str,
    churn: Option<ChurnSchedule>,
    failover: bool,
}

/// The scenario list for `env`'s cluster. The "cleanest" device is the
/// Jetson when present (the paper cluster), device 0 otherwise.
fn scenarios(env: &Env) -> Vec<Scenario> {
    let cleanest = env
        .cluster
        .devices
        .iter()
        .position(|d| d.name == "jetson-orin-nx")
        .unwrap_or(0);
    let lost = ChurnSchedule::scripted(vec![OutageWindow {
        device: cleanest,
        start_s: OUTAGE_START_S,
        end_s: 1e9,
    }])
    .expect("valid scripted window");
    let flaky = ChurnSchedule::stochastic(
        env.cluster.devices.len(),
        300.0,
        60.0,
        1800.0,
        &mut Rng::new(0x5EED_C0DE),
    )
    .expect("valid stochastic schedule");
    vec![
        Scenario { name: "always-up", churn: None, failover: true },
        Scenario { name: "cleanest-down", churn: Some(lost.clone()), failover: true },
        Scenario { name: "cleanest-down-nofail", churn: Some(lost), failover: false },
        Scenario { name: "flaky", churn: Some(flaky), failover: true },
    ]
}

/// Run the strategy × scenario matrix through the DES.
pub fn run(env: &Env) -> (Vec<ChurnRow>, Table) {
    let mut rows = Vec::new();
    for scenario in scenarios(env) {
        for strategy in STRATEGIES {
            let cfg = OnlineConfig {
                batch_size: env.cfg.serving.batch_size,
                strategy: strategy.into(),
                churn: scenario.churn.clone(),
                failover: scenario.failover,
                ..OnlineConfig::default()
            };
            let r = run_online(&env.cluster, &env.prompts, &env.db, &cfg)
                .expect("bench strategies resolve");
            assert_eq!(
                r.completed + r.shed,
                env.prompts.len(),
                "conservation: every prompt completes or is counted shed \
                 ({strategy} / {})",
                scenario.name
            );
            let f = r.ledger.failure_stats();
            rows.push(ChurnRow {
                strategy: strategy.into(),
                scenario: scenario.name,
                completed: r.completed,
                shed: r.shed,
                failovers: f.failovers,
                requeues: f.requeues,
                outages: f.outages,
                carbon_kg: r.ledger.total_carbon_kg(),
                latency_mean_s: r.latency.mean(),
                deadline_violations: r.deadline_violations,
            });
        }
    }

    let mut table = Table::new(
        "BENCH_churn",
        "Device churn: strategy × availability scenario (DES plane)",
        &[
            "Strategy",
            "Scenario",
            "Completed",
            "Shed",
            "Failovers",
            "Requeues",
            "Outages",
            "Carbon kgCO2e",
            "Mean E2E s",
            "Deadline viol.",
        ],
    );
    for r in &rows {
        table.row(vec![
            r.strategy.clone(),
            r.scenario.to_string(),
            r.completed.to_string(),
            r.shed.to_string(),
            r.failovers.to_string(),
            r.requeues.to_string(),
            r.outages.to_string(),
            fmt::sci(r.carbon_kg),
            fmt::secs(r.latency_mean_s),
            r.deadline_violations.to_string(),
        ]);
    }
    table.note(format!(
        "cleanest-down kills device hosting the cleanest model at t={OUTAGE_START_S}s \
         and keeps it down; -nofail sheds disrupted work instead of migrating it; \
         flaky is a seeded stochastic MTBF/MTTR schedule. completed + shed always \
         equals the corpus size."
    ));
    (rows, table)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row<'a>(rows: &'a [ChurnRow], strategy: &str, scenario: &str) -> &'a ChurnRow {
        rows.iter()
            .find(|r| r.strategy == strategy && r.scenario == scenario)
            .unwrap_or_else(|| panic!("missing row {strategy}/{scenario}"))
    }

    #[test]
    fn failover_keeps_shed_below_the_no_failover_baseline() {
        let env = Env::small(32);
        let (rows, table) = run(&env);
        assert_eq!(rows.len(), STRATEGIES.len() * 4);
        assert_eq!(table.name, "BENCH_churn");

        for r in &rows {
            // run() already asserts conservation; spot-check the rows
            assert_eq!(r.completed + r.shed, 32, "{}/{}", r.strategy, r.scenario);
        }
        // churn off: no failure machinery fires at all
        for r in rows.iter().filter(|r| r.scenario == "always-up") {
            assert_eq!(r.shed, 0, "{}", r.strategy);
            assert_eq!(r.failovers + r.requeues + r.outages, 0, "{}", r.strategy);
        }

        // the tentpole contrast: with everything pinned to the dying
        // device, failover migrates the disrupted work (zero shed)
        // while the no-failover baseline sheds it
        let with = row(&rows, "all-on-jetson-orin-nx", "cleanest-down");
        let without = row(&rows, "all-on-jetson-orin-nx", "cleanest-down-nofail");
        assert_eq!(with.shed, 0, "failover must rescue every disrupted prompt");
        assert!(
            with.failovers + with.requeues > 0,
            "the outage must actually disrupt in-flight or queued work"
        );
        assert!(without.shed > 0, "no-failover must shed disrupted work");
        assert!(with.shed < without.shed, "failover must beat the baseline");

        // the issue's headline: the forecast router must not collapse
        // when its cleanest device fails
        let f = row(&rows, "forecast-carbon-aware", "cleanest-down");
        assert_eq!(f.shed, 0, "forecast-carbon-aware must keep serving through the outage");
        assert_eq!(f.completed, 32);
    }
}
