//! Table 3 reproduction: strategy comparison across batch sizes.
//!
//! The paper's headline table: total E2E latency (cluster makespan) and
//! total carbon footprint for {All-on-Jetson, All-on-Ada, Carbon-Aware,
//! Latency-Aware} at batch 1/4/8, over the 500-prompt sample. We add the
//! extension strategies (round-robin, complexity-aware, carbon-cap) as
//! extra rows, plus the device routing share the paper quotes in prose
//! ("~85 % of prompts to the Jetson").

use crate::config::ExecutionMode;
use crate::coordinator::{run as run_sched, Grouping, PlacementPolicy, RunConfig};
use crate::report::{fmt, Table};

use super::Env;

/// Paper strategies, in Table 3 order.
pub const PAPER_STRATEGIES: [&str; 4] =
    ["all-on-jetson-orin-nx", "all-on-ada-2000", "carbon-aware", "latency-aware"];

/// Extension strategies appended to each batch block.
pub const EXTENSION_STRATEGIES: [&str; 3] =
    ["round-robin", "complexity-aware", "carbon-cap@2e-5"];

/// One row of Table 3.
#[derive(Debug, Clone)]
pub struct Table3Row {
    pub batch: usize,
    pub strategy: String,
    pub total_e2e_s: f64,
    pub total_carbon_kg: f64,
    pub jetson_share: f64,
    pub error_rate: f64,
}

/// Run the experiment. `extensions` appends the non-paper strategies.
pub fn run(env: &Env, extensions: bool) -> (Vec<Table3Row>, Table) {
    let mut rows = Vec::new();
    let mut names: Vec<&str> = PAPER_STRATEGIES.to_vec();
    if extensions {
        names.extend(EXTENSION_STRATEGIES);
    }
    for &batch in &[1usize, 4, 8] {
        for name in &names {
            let strategy = PlacementPolicy::spatial(name, &env.cluster).expect("strategy");
            let cfg = RunConfig {
                batch_size: batch,
                grouping: Grouping::Fifo,
                execution: ExecutionMode::Calibrated,
                max_new_tokens: env.cfg.serving.max_new_tokens,
                stochastic_seed: None,
                continuous_batching: false,
                ..RunConfig::default()
            };
            let r = run_sched(&env.cluster, &env.prompts, &strategy, &env.db, &cfg, None)
                .expect("table3 run");
            rows.push(Table3Row {
                batch,
                strategy: r.strategy.clone(),
                total_e2e_s: r.makespan_s,
                total_carbon_kg: r.total_carbon_kg,
                jetson_share: r.share("jetson-orin-nx"),
                error_rate: r.overall.error_rate(),
            });
        }
    }

    // mark the winners per batch block like the paper does
    let mut table = Table::new(
        "table3",
        "Table 3 — LLM inference strategies across batch sizes 1, 4, 8 (500 prompts)",
        &["Batch", "Strategy", "Total E2E latency (s)", "Total Carbon (kgCO2e)", "Jetson share", "Err"],
    );
    for &batch in &[1usize, 4, 8] {
        let block: Vec<&Table3Row> = rows.iter().filter(|r| r.batch == batch).collect();
        let best_lat = block
            .iter()
            .map(|r| r.total_e2e_s)
            .fold(f64::MAX, f64::min);
        let best_carbon = block
            .iter()
            .map(|r| r.total_carbon_kg)
            .fold(f64::MAX, f64::min);
        for r in block {
            let lat = if (r.total_e2e_s - best_lat).abs() < 1e-9 {
                format!("{} (lowest)", fmt::secs(r.total_e2e_s))
            } else {
                fmt::secs(r.total_e2e_s)
            };
            let carbon = if (r.total_carbon_kg - best_carbon).abs() < 1e-15 {
                format!("{} (lowest)", fmt::sci(r.total_carbon_kg))
            } else {
                fmt::sci(r.total_carbon_kg)
            };
            table.row(vec![
                r.batch.to_string(),
                r.strategy.clone(),
                lat,
                carbon,
                fmt::pct(r.jetson_share),
                fmt::pct(r.error_rate),
            ]);
        }
    }
    table.note("total E2E = cluster makespan, all prompts queued at t=0 (closed loop)");
    table.note("absolute values are calibrated to the paper's Table 2 per-request \
                measurements; Table 3 of the paper is internally inconsistent with \
                its own Table 2 (see EXPERIMENTS.md), orderings and ratios hold");
    (rows, table)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get<'a>(rows: &'a [Table3Row], b: usize, s: &str) -> &'a Table3Row {
        rows.iter().find(|r| r.batch == b && r.strategy.contains(s)).unwrap()
    }

    #[test]
    fn headline_claims_hold_at_every_batch() {
        let env = Env::small(160);
        let (rows, _) = run(&env, false);
        assert_eq!(rows.len(), 12);

        for b in [1usize, 4, 8] {
            let jetson = get(&rows, b, "all-on-jetson");
            let ada = get(&rows, b, "all-on-ada");
            let carbon = get(&rows, b, "carbon-aware");
            let latency = get(&rows, b, "latency-aware");

            // claim 1: carbon-aware has the lowest carbon
            for other in [jetson, ada, latency] {
                assert!(
                    carbon.total_carbon_kg <= other.total_carbon_kg * 1.0001,
                    "b{b}: carbon-aware {} vs {} {}",
                    carbon.total_carbon_kg,
                    other.strategy,
                    other.total_carbon_kg
                );
            }
            // claim 2: latency-aware has the lowest total E2E
            for other in [jetson, ada, carbon] {
                assert!(
                    latency.total_e2e_s < other.total_e2e_s,
                    "b{b}: latency-aware {} vs {} {}",
                    latency.total_e2e_s,
                    other.strategy,
                    other.total_e2e_s
                );
            }
            // claim 3: 2-3x (or better) vs the Jetson-only baseline at
            // batch 1/4; at batch 8 the Jetson-only baseline itself gets
            // faster (Table 2: its b8 E2E ~= b4), compressing the gap
            let speedup = jetson.total_e2e_s / latency.total_e2e_s;
            let floor = if b == 8 { 1.6 } else { 2.0 };
            assert!(speedup >= floor, "b{b}: speedup {speedup}");
            // claim 4: Ada-only faster but dirtier than Jetson-only
            assert!(ada.total_e2e_s < jetson.total_e2e_s, "b{b}");
            assert!(ada.total_carbon_kg > jetson.total_carbon_kg, "b{b}");
            // carbon-aware routes the bulk of prompts to the Jetson
            assert!(carbon.jetson_share > 0.7, "b{b}: share {}", carbon.jetson_share);
            // latency-aware genuinely uses both devices
            assert!(
                latency.jetson_share > 0.05 && latency.jetson_share < 0.95,
                "b{b}: share {}",
                latency.jetson_share
            );
        }
    }

    #[test]
    fn carbon_reduction_vs_worst_baseline_is_large() {
        // paper: "reduce emissions by up to 35 %" vs greedy baselines;
        // with Table-2 physics the gap vs Ada-only is even larger
        let env = Env::small(160);
        let (rows, _) = run(&env, false);
        for b in [1usize, 4, 8] {
            let ada = get(&rows, b, "all-on-ada");
            let carbon = get(&rows, b, "carbon-aware");
            let reduction = 1.0 - carbon.total_carbon_kg / ada.total_carbon_kg;
            assert!(reduction > 0.35, "b{b}: reduction {reduction}");
        }
    }

    #[test]
    fn extensions_append_rows() {
        let env = Env::small(60);
        let (rows, table) = run(&env, true);
        assert_eq!(rows.len(), 21);
        assert!(table.ascii().contains("round-robin"));
        // carbon-cap sits between carbon-aware and latency-aware on carbon
        for b in [4usize] {
            let cap = get(&rows, b, "carbon-cap");
            let carbon = get(&rows, b, "carbon-aware");
            assert!(cap.total_carbon_kg >= carbon.total_carbon_kg * 0.9999);
            assert!(cap.total_e2e_s <= carbon.total_e2e_s * 1.0001);
        }
    }

    #[test]
    fn winners_marked_in_render() {
        let env = Env::small(60);
        let (_, table) = run(&env, false);
        let ascii = table.ascii();
        assert!(ascii.matches("(lowest)").count() >= 6);
    }
}
