//! Cross-batch analysis (paper §3, closing paragraphs): how TTFT,
//! per-prompt carbon, throughput and stability move with batch size.
//!
//! Claims to reproduce:
//! - latency per prompt decreases with batch (parallel token generation
//!   amortizes TPOT) but **TTFT increases significantly**;
//! - **carbon per prompt declines** with batching (energy amortized);
//! - the Jetson exhibits errors at batch 8 (memory saturation) while the
//!   Ada stays stable — "batch 8 demands at least 16 GB";
//! - batch 4 is the overall sweet spot.
//!
//! We sweep batch ∈ {1, 2, 4, 8, 16} for the latency-aware strategy plus
//! both single-device baselines.

use crate::config::ExecutionMode;
use crate::coordinator::{run as run_sched, Grouping, PlacementPolicy, RunConfig};
use crate::report::{fmt, Table};

use super::Env;

pub const BATCHES: [usize; 5] = [1, 2, 4, 8, 16];

/// One sweep point.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub strategy: String,
    pub batch: usize,
    pub makespan_s: f64,
    pub mean_ttft_s: f64,
    pub carbon_per_prompt_kg: f64,
    pub throughput_tps: f64,
    pub error_rate: f64,
}

/// Run the sweep and return (rows, rendered table).
pub fn run(env: &Env) -> (Vec<SweepRow>, Table) {
    let strategies = ["all-on-jetson-orin-nx", "all-on-ada-2000", "latency-aware"];
    let mut rows = Vec::new();
    for name in strategies {
        for &batch in &BATCHES {
            let strategy = PlacementPolicy::spatial(name, &env.cluster).expect("strategy");
            let cfg = RunConfig {
                batch_size: batch,
                grouping: Grouping::Fifo,
                execution: ExecutionMode::Calibrated,
                max_new_tokens: env.cfg.serving.max_new_tokens,
                stochastic_seed: None,
                continuous_batching: false,
                ..RunConfig::default()
            };
            let r = run_sched(&env.cluster, &env.prompts, &strategy, &env.db, &cfg, None)
                .expect("sweep run");
            let n = r.metrics.len() as f64;
            let ttft: f64 =
                r.metrics.iter().map(|m| m.ttft_s - m.queue_s).sum::<f64>() / n;
            let tokens: f64 = r.metrics.iter().map(|m| m.output_tokens as f64).sum();
            rows.push(SweepRow {
                strategy: r.strategy.clone(),
                batch,
                makespan_s: r.makespan_s,
                mean_ttft_s: ttft,
                carbon_per_prompt_kg: r.total_carbon_kg / n,
                throughput_tps: tokens / r.makespan_s.max(1e-9),
                error_rate: r.overall.error_rate(),
            });
        }
    }

    let mut table = Table::new(
        "sweep",
        "Cross-batch sweep — batch in {1,2,4,8,16} per strategy",
        &["Strategy", "Batch", "Makespan (s)", "TTFT (s)", "Carbon/prompt (kg)", "Cluster tok/s", "Err"],
    );
    for r in &rows {
        table.row(vec![
            r.strategy.clone(),
            r.batch.to_string(),
            fmt::secs(r.makespan_s),
            fmt::secs(r.mean_ttft_s),
            fmt::sci(r.carbon_per_prompt_kg),
            fmt::f2(r.throughput_tps),
            fmt::pct(r.error_rate),
        ]);
    }
    table.note("batch 16 exceeds the paper's sweep — it probes the saturation wall");
    (rows, table)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series<'a>(rows: &'a [SweepRow], strat: &str) -> Vec<&'a SweepRow> {
        rows.iter().filter(|r| r.strategy.contains(strat)).collect()
    }

    fn at<'a>(rows: &'a [SweepRow], strat: &str, b: usize) -> &'a SweepRow {
        rows.iter().find(|r| r.strategy.contains(strat) && r.batch == b).unwrap()
    }

    #[test]
    fn cross_batch_claims_hold() {
        let env = Env::small(160);
        let (rows, _) = run(&env);
        assert_eq!(rows.len(), 15);

        for strat in ["all-on-jetson", "all-on-ada", "latency-aware"] {
            let s = series(&rows, strat);
            // TTFT increases with batch size
            for w in s.windows(2) {
                assert!(
                    w[1].mean_ttft_s > w[0].mean_ttft_s * 0.999,
                    "{strat}: TTFT not rising at batch {}",
                    w[1].batch
                );
            }
            // carbon per prompt falls from batch 1 to batch 4
            assert!(
                at(&rows, strat, 4).carbon_per_prompt_kg
                    < at(&rows, strat, 1).carbon_per_prompt_kg,
                "{strat}"
            );
        }
        // makespan improves from batch 1 to batch 4 where decode
        // amortization wins (Jetson, cluster-wide latency-aware); on the
        // Ada the serialized-prefill TTFT cancels it (Table 2: b4 E2E/4
        // ~= b1 E2E) so it only has to stay flat
        for strat in ["all-on-jetson", "latency-aware"] {
            assert!(
                at(&rows, strat, 4).makespan_s < at(&rows, strat, 1).makespan_s,
                "{strat}"
            );
        }
        {
            // Table 2 implies Ada batching is ~neutral (b4 E2E/4 = 3.65 s
            // vs b1 3.39 s); realized mixed batches add decode-straggler
            // cost on top, so the band is loose but bounded
            let a1 = at(&rows, "all-on-ada", 1).makespan_s;
            let a4 = at(&rows, "all-on-ada", 4).makespan_s;
            assert!(a4 < a1 * 1.45 && a4 > a1 * 0.8, "ada drifted: {a1} vs {a4}");
        }

        // Jetson unstable at batch >= 8, Ada stable at batch 8
        assert!(at(&rows, "all-on-jetson", 8).error_rate >= 0.0);
        assert!(
            at(&rows, "all-on-jetson", 16).error_rate
                > at(&rows, "all-on-jetson", 1).error_rate
        );
        assert!(at(&rows, "all-on-ada", 8).error_rate < 0.05);
    }

    #[test]
    fn batch4_is_the_sweet_spot() {
        // the paper's takeaway: batch 4 balances latency, carbon and
        // stability. Score each batch by normalized (makespan, carbon,
        // errors) for the latency-aware strategy; 4 must win over 1 & 16.
        let env = Env::small(160);
        let (rows, _) = run(&env);
        // score = normalized makespan + carbon + stability + a small
        // responsiveness (TTFT) term, on the Jetson series — the device
        // the paper's instability claim is about. The TTFT term encodes
        // the paper's "batch 8 limits responsiveness" argument.
        let score = |b: usize| {
            let r = at(&rows, "all-on-jetson", b);
            let base = at(&rows, "all-on-jetson", 1);
            r.makespan_s / base.makespan_s
                + r.carbon_per_prompt_kg / base.carbon_per_prompt_kg
                + 0.1 * r.mean_ttft_s / base.mean_ttft_s
                + r.error_rate * 20.0
        };
        for b in [1usize, 2, 8, 16] {
            assert!(score(4) < score(b), "batch 4 {} vs batch {b} {}", score(4), score(b));
        }
    }
}
