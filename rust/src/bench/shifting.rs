//! Temporal-shifting sweep: strategies × grid traces × deferrable
//! fractions (the grid subsystem's headline experiment).
//!
//! Replays the same corpus — arrivals spread across a day, a seeded
//! fraction marked `Deferrable` with a 10 h completion deadline — under
//! the paper's arrival-time carbon-aware strategy and under
//! forecast-carbon-aware with deferral, over a constant trace (control:
//! shifting can't help), the diurnal duck curve, and a noisy synthetic
//! week. Reported carbon is the ledger's realized total; savings are
//! attributed against the run-at-arrival counterfactual; deadline
//! violations and interactive latency guard the SLO side of the trade.
//!
//! `verdant bench shifting` also prints the forecaster scoreboard
//! ([`scores`]): MAPE/bias of every forecaster on the held-out tail of
//! the noisy trace — the evidence for defaulting to the harmonic model.
//!
//! The third table ([`drift`]) is the receding-horizon showcase: a
//! drift-injected ground truth (a wind-lull ramp wipes out the
//! overnight clean window every arrival-time forecast promised) run
//! plan-once vs with `replan` on. Re-planning detects the
//! realized-vs-forecast divergence online and releases held work early
//! — lower carbon at the same (zero) deadline-violation count.
//!
//! The fourth table ([`blend_curves`]) sweeps the drift-blend weight
//! curve (linear / clamped-quadratic / step) on the same drift trace —
//! the evidence behind [`BlendCurve::ClampedQuadratic`] as the
//! default.

use crate::cluster::{CarbonModel, Cluster};
use crate::config::Arrival;
use crate::coordinator::online::{run_online, BatchPolicy, GridShiftConfig, OnlineConfig};
use crate::coordinator::BlendCurve;
use crate::grid::{score, ForecastKind, ForecastScore, GridTrace, SyntheticTrace};
use crate::report::{fmt, Table};
use crate::workload::{trace, Corpus};

use super::Env;

/// Deferrable fractions swept.
pub const DEFER_FRACS: [f64; 3] = [0.0, 0.3, 0.6];

/// Completion deadline for deferrable prompts (10 h).
pub const DEADLINE_S: f64 = 10.0 * 3600.0;

/// Arrival window the corpus is spread over (18 h of one day).
pub const ARRIVAL_SPAN_S: f64 = 18.0 * 3600.0;

/// One sweep point.
#[derive(Debug, Clone)]
pub struct ShiftingRow {
    pub trace: String,
    pub strategy: String,
    pub defer_frac: f64,
    /// Realized corpus carbon (active energy), kgCO2e.
    pub carbon_kg: f64,
    /// Realized savings vs the run-at-arrival counterfactual, fraction.
    pub savings_frac: f64,
    pub deferred: usize,
    pub deadline_violations: usize,
    pub interactive_lat_s: f64,
    pub completed: usize,
}

/// The grid traces swept (name, trace).
pub fn traces() -> Vec<GridTrace> {
    vec![
        GridTrace::constant(69.0),
        CarbonModel::diurnal(69.0, 0.3).to_trace(900.0),
        SyntheticTrace {
            name: "diurnal-noisy".into(),
            mean_g_per_kwh: 69.0,
            diurnal_swing: 0.3,
            weekly_swing: 0.1,
            noise_frac: 0.08,
            days: 7,
            step_s: 900.0,
            seed: 4242,
        }
        .generate(),
    ]
}

/// Run the sweep and return (rows, rendered table).
pub fn run(env: &Env) -> (Vec<ShiftingRow>, Table) {
    let mut rows = Vec::new();
    let base = &env.cfg;
    let n = base.workload.prompts;
    let rate = n as f64 / ARRIVAL_SPAN_S;

    for grid_trace in traces() {
        let mut cluster = Cluster::from_config(&base.cluster);
        cluster.carbon = CarbonModel::from_trace(grid_trace.clone()).into();
        for &frac in &DEFER_FRACS {
            // identical corpus + SLO marking for every strategy at this point
            let mut corpus = Corpus::generate(&base.workload);
            trace::assign_arrivals(&mut corpus.prompts, Arrival::Open { rate }, base.workload.seed);
            trace::assign_slos(&mut corpus.prompts, frac, DEADLINE_S, base.workload.seed ^ 0x51);

            for (strategy, shifting) in
                [("carbon-aware", false), ("forecast-carbon-aware", true)]
            {
                let cfg = OnlineConfig {
                    batch_size: base.serving.batch_size,
                    policy: BatchPolicy::Immediate,
                    strategy: strategy.into(),
                    grid: shifting
                        .then(|| GridShiftConfig::new(grid_trace.clone(), ForecastKind::Harmonic)),
                    ..OnlineConfig::default()
                };
                let r = run_online(&cluster, &corpus.prompts, &env.db, &cfg)
                    .expect("bench strategies resolve");
                let (_, _, carbon_kg) = r.ledger.totals();
                rows.push(ShiftingRow {
                    trace: grid_trace.name.clone(),
                    strategy: strategy.into(),
                    defer_frac: frac,
                    carbon_kg,
                    savings_frac: r.ledger.savings_frac(),
                    deferred: r.deferred,
                    deadline_violations: r.deadline_violations,
                    interactive_lat_s: if r.latency_interactive.count() > 0 {
                        r.latency_interactive.mean()
                    } else {
                        0.0
                    },
                    completed: r.completed,
                });
            }
        }
    }

    let mut table = Table::new(
        "shifting",
        "Temporal shifting — strategy × grid trace × deferrable fraction",
        &["Trace", "Strategy", "Defer", "Carbon (kgCO2e)", "Saved vs arrival", "Held",
          "Viol", "Int lat (s)"],
    );
    for r in &rows {
        table.row(vec![
            r.trace.clone(),
            r.strategy.clone(),
            format!("{:.0}%", r.defer_frac * 100.0),
            fmt::sci(r.carbon_kg),
            fmt::signed_pct(r.savings_frac),
            r.deferred.to_string(),
            r.deadline_violations.to_string(),
            fmt::secs(r.interactive_lat_s),
        ]);
    }
    table.note(format!(
        "open-loop DES, {n} prompts over {:.0} h, deferrable deadline {:.0} h, \
         harmonic forecaster; savings attributed vs the run-at-arrival counterfactual",
        ARRIVAL_SPAN_S / 3600.0,
        DEADLINE_S / 3600.0
    ));
    (rows, table)
}

/// One plan-once-vs-replan comparison point on the drift trace.
#[derive(Debug, Clone)]
pub struct DriftRow {
    /// "plan-once" or "replan".
    pub mode: &'static str,
    pub carbon_kg: f64,
    pub savings_frac: f64,
    pub deferred: usize,
    pub deadline_violations: usize,
    /// Replan passes executed (0 for plan-once).
    pub replans: u64,
    /// Holds a replan released earlier than planned.
    pub released_early: u64,
    /// Holds a replan extended toward a cleaner window.
    pub extended: u64,
    pub completed: usize,
}

/// Drift-injected ground truth: three clean diurnal days, then a
/// wind-lull ramp through the early hours of day 4 — intensity climbs
/// +120 g/kWh over three hours starting at 71 h and stays elevated
/// until 77 h. A forecaster fitted on the clean history cannot see it
/// coming, so every overnight clean window planned before 71 h is a
/// phantom: plan-once releases held work straight into the ramp, while
/// the drift monitor watches realized-vs-forecast error climb and
/// re-plans.
pub fn drift_trace() -> GridTrace {
    let diurnal = CarbonModel::diurnal(69.0, 0.3);
    GridTrace::from_fn("drift-ramp", 900.0, 4 * 96, |t| {
        let h = t / 3600.0;
        let base = diurnal.intensity_at(t);
        if (71.0..77.0).contains(&h) {
            base + 120.0 * ((h - 71.0) / 3.0).min(1.0)
        } else {
            base
        }
    })
}

/// Run the drift scenario plan-once and with re-planning and return
/// (rows, rendered table). Arrivals land in the day-3 evening ramp
/// (66 h) so each deferrable prompt's 10 h deadline reaches exactly
/// into the phantom overnight window.
pub fn drift(env: &Env) -> (Vec<DriftRow>, Table) {
    let base = &env.cfg;
    let n = base.workload.prompts;
    let grid_trace = drift_trace();
    let mut cluster = Cluster::from_config(&base.cluster);
    cluster.carbon = CarbonModel::from_trace(grid_trace.clone()).into();

    let mut corpus = Corpus::generate(&base.workload);
    // ~2 h arrival burst starting at 66 h (18:00 on day 3)
    trace::assign_arrivals(
        &mut corpus.prompts,
        Arrival::Open { rate: n as f64 / 7200.0 },
        base.workload.seed,
    );
    for p in &mut corpus.prompts {
        p.arrival_s += 66.0 * 3600.0;
    }
    trace::assign_slos(&mut corpus.prompts, 0.6, DEADLINE_S, base.workload.seed ^ 0x51);

    let mut rows = Vec::new();
    for (mode, replan) in [("plan-once", false), ("replan", true)] {
        let cfg = OnlineConfig {
            batch_size: base.serving.batch_size,
            policy: BatchPolicy::Immediate,
            strategy: "forecast-carbon-aware".into(),
            grid: Some(
                GridShiftConfig::new(grid_trace.clone(), ForecastKind::Harmonic)
                    .with_replan(replan),
            ),
            ..OnlineConfig::default()
        };
        let r = run_online(&cluster, &corpus.prompts, &env.db, &cfg)
            .expect("bench strategies resolve");
        let (_, _, carbon_kg) = r.ledger.totals();
        let stats = r.ledger.replan_stats();
        rows.push(DriftRow {
            mode,
            carbon_kg,
            savings_frac: r.ledger.savings_frac(),
            deferred: r.deferred,
            deadline_violations: r.deadline_violations,
            replans: stats.passes,
            released_early: stats.released_early,
            extended: stats.extended,
            completed: r.completed,
        });
    }

    let mut table = Table::new(
        "shifting_drift",
        "Receding-horizon re-planning on a drift-injected trace (plan-once vs replan)",
        &["Mode", "Carbon (kgCO2e)", "Saved vs arrival", "Held", "Viol", "Replans",
          "Early", "Extended"],
    );
    for r in &rows {
        table.row(vec![
            r.mode.to_string(),
            fmt::sci(r.carbon_kg),
            fmt::signed_pct(r.savings_frac),
            r.deferred.to_string(),
            r.deadline_violations.to_string(),
            r.replans.to_string(),
            r.released_early.to_string(),
            r.extended.to_string(),
        ]);
    }
    table.note(format!(
        "{n} prompts arriving at 66 h on the drift-ramp trace (wind lull 71-77 h), \
         60% deferrable (deadline {:.0} h), forecast-carbon-aware, harmonic forecaster; \
         replan = drift threshold 0.2, window 8 steps, cadence one trace step",
        DEADLINE_S / 3600.0
    ));
    (rows, table)
}

/// One blend-weight-curve comparison point on the drift trace.
#[derive(Debug, Clone)]
pub struct BlendCurveRow {
    /// Curve label ([`BlendCurve::name`]).
    pub curve: &'static str,
    pub carbon_kg: f64,
    pub savings_frac: f64,
    pub deferred: usize,
    pub deadline_violations: usize,
    pub completed: usize,
}

/// Sweep the drift-blend weight curve on the drift-injected trace:
/// with blending on, the rolling MAPE `m` discounts the fitted
/// forecast toward persistence with weight `w = curve(m / threshold)`
/// — [`BlendCurve::Linear`] trusts the fit proportionally,
/// [`BlendCurve::ClampedQuadratic`] (the default: cautious early,
/// decisive once drift is confirmed) suppresses small-noise reactions,
/// and [`BlendCurve::Step`] is the binary trust/distrust switch. The
/// drift ramp is where the curves separate: before it `m ~ 0` and all
/// three plan identically; through it the shape decides how fast held
/// work stops believing the phantom overnight window.
pub fn blend_curves(env: &Env) -> (Vec<BlendCurveRow>, Table) {
    let base = &env.cfg;
    let n = base.workload.prompts;
    let grid_trace = drift_trace();
    let mut cluster = Cluster::from_config(&base.cluster);
    cluster.carbon = CarbonModel::from_trace(grid_trace.clone()).into();

    let mut corpus = Corpus::generate(&base.workload);
    trace::assign_arrivals(
        &mut corpus.prompts,
        Arrival::Open { rate: n as f64 / 7200.0 },
        base.workload.seed,
    );
    for p in &mut corpus.prompts {
        p.arrival_s += 66.0 * 3600.0;
    }
    trace::assign_slos(&mut corpus.prompts, 0.6, DEADLINE_S, base.workload.seed ^ 0x51);

    let mut rows = Vec::new();
    for curve in [BlendCurve::Linear, BlendCurve::ClampedQuadratic, BlendCurve::Step] {
        let cfg = OnlineConfig {
            batch_size: base.serving.batch_size,
            policy: BatchPolicy::Immediate,
            strategy: "forecast-carbon-aware".into(),
            grid: Some(
                GridShiftConfig::new(grid_trace.clone(), ForecastKind::Harmonic)
                    .with_blend(true)
                    .with_blend_curve(curve),
            ),
            ..OnlineConfig::default()
        };
        let r = run_online(&cluster, &corpus.prompts, &env.db, &cfg)
            .expect("bench strategies resolve");
        let (_, _, carbon_kg) = r.ledger.totals();
        rows.push(BlendCurveRow {
            curve: curve.name(),
            carbon_kg,
            savings_frac: r.ledger.savings_frac(),
            deferred: r.deferred,
            deadline_violations: r.deadline_violations,
            completed: r.completed,
        });
    }

    let mut table = Table::new(
        "shifting_blend_curve",
        "Drift-blend weight curve sweep on the drift-injected trace",
        &["Curve", "Carbon (kgCO2e)", "Saved vs arrival", "Held", "Viol"],
    );
    for r in &rows {
        table.row(vec![
            r.curve.to_string(),
            fmt::sci(r.carbon_kg),
            fmt::signed_pct(r.savings_frac),
            r.deferred.to_string(),
            r.deadline_violations.to_string(),
        ]);
    }
    table.note(format!(
        "{n} prompts arriving at 66 h on the drift-ramp trace, 60% deferrable \
         (deadline {:.0} h), forecast-carbon-aware with drift-aware blending on; \
         w = curve(MAPE / threshold) discounts the fit toward persistence; \
         clamped_quadratic is the default (ignores noise-level MAPE, converges \
         to persistence as fast as linear once drift is confirmed)",
        DEADLINE_S / 3600.0
    ));
    (rows, table)
}

/// Forecaster scoreboard on the held-out tail of the noisy weekly trace.
pub fn scores(_env: &Env) -> (Vec<ForecastScore>, Table) {
    let noisy = traces().pop().expect("traces() is non-empty");
    let period = noisy.steps_per_day();
    let results: Vec<ForecastScore> = ForecastKind::ALL
        .iter()
        .map(|k| score(k.build(period).as_ref(), &noisy, 0.25))
        .collect();

    let mut table = Table::new(
        "shifting_forecasters",
        "Forecaster accuracy — 25% held-out tail of the noisy weekly trace",
        &["Forecaster", "MAPE", "Bias (g/kWh)", "Horizon (steps)"],
    );
    for s in &results {
        table.row(vec![
            s.forecaster.clone(),
            fmt::pct(s.mape),
            format!("{:+.2}", s.bias_g),
            s.horizon.to_string(),
        ]);
    }
    table.note("one-shot forecast of the whole tail (no feedback), daily seasonal period");
    (results, table)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get<'a>(rows: &'a [ShiftingRow], tr: &str, strat: &str, frac: f64) -> &'a ShiftingRow {
        rows.iter()
            .find(|r| r.trace == tr && r.strategy == strat && (r.defer_frac - frac).abs() < 1e-9)
            .unwrap()
    }

    #[test]
    fn shifting_cuts_diurnal_carbon_without_breaking_slos() {
        let env = Env::small(200);
        let (rows, table) = run(&env);
        assert_eq!(rows.len(), 3 * 3 * 2);
        assert!(table.ascii().contains("forecast-carbon-aware"));

        // every run completes the whole corpus with zero deadline misses
        for r in &rows {
            assert_eq!(r.completed, 200, "{}/{}", r.trace, r.strategy);
            assert_eq!(r.deadline_violations, 0, "{}/{}", r.trace, r.strategy);
        }

        // headline: ≥10 % corpus carbon cut vs arrival-time carbon-aware
        // on the diurnal trace at the highest deferrable fraction
        let base = get(&rows, "diurnal", "carbon-aware", 0.6);
        let shifted = get(&rows, "diurnal", "forecast-carbon-aware", 0.6);
        let cut = 1.0 - shifted.carbon_kg / base.carbon_kg;
        assert!(cut >= 0.10, "carbon cut {:.3} < 10%", cut);
        assert!(shifted.deferred > 0);
        assert!(shifted.savings_frac > 0.05, "savings {:.3}", shifted.savings_frac);

        // interactive latency is not sacrificed for the savings
        assert!(
            shifted.interactive_lat_s < base.interactive_lat_s * 1.10,
            "interactive {} vs {}",
            shifted.interactive_lat_s,
            base.interactive_lat_s
        );

        // control: on the constant trace shifting cannot help
        let cbase = get(&rows, "constant", "carbon-aware", 0.6);
        let cshift = get(&rows, "constant", "forecast-carbon-aware", 0.6);
        assert!((cshift.carbon_kg - cbase.carbon_kg).abs() / cbase.carbon_kg < 0.02);
        assert!(cshift.savings_frac.abs() < 0.01);

        // with nothing deferrable the strategies coincide on carbon
        let z_base = get(&rows, "diurnal", "carbon-aware", 0.0);
        let z_shift = get(&rows, "diurnal", "forecast-carbon-aware", 0.0);
        assert_eq!(z_shift.deferred, 0);
        assert!((z_shift.carbon_kg - z_base.carbon_kg).abs() / z_base.carbon_kg < 0.05);

        // more deferrable load -> materially more saving (batching
        // differences allow a little slop between the two runs)
        let mid = get(&rows, "diurnal", "forecast-carbon-aware", 0.3);
        assert!(
            shifted.savings_frac >= mid.savings_frac * 0.8,
            "savings at 60% {:.3} vs 30% {:.3}",
            shifted.savings_frac,
            mid.savings_frac
        );
    }

    #[test]
    fn replan_beats_plan_once_on_the_drift_trace() {
        let env = Env::small(160);
        let (rows, table) = drift(&env);
        assert_eq!(rows.len(), 2);
        assert!(table.ascii().contains("replan"));
        let once = rows.iter().find(|r| r.mode == "plan-once").unwrap();
        let re = rows.iter().find(|r| r.mode == "replan").unwrap();

        // both complete the corpus; the phantom window must actually
        // have attracted holds for the comparison to mean anything
        assert_eq!(once.completed, 160);
        assert_eq!(re.completed, 160);
        assert!(once.deferred > 0, "plan-once held nothing — scenario broken");
        assert_eq!(once.replans, 0);

        // the replanner ran, noticed the drift, and released early
        assert!(re.replans > 0, "no replan pass fired");
        assert!(re.released_early > 0, "drift never released a hold early");

        // headline: lower carbon at an equal deadline-violation count
        assert_eq!(once.deadline_violations, 0);
        assert_eq!(re.deadline_violations, once.deadline_violations);
        assert!(
            re.carbon_kg < once.carbon_kg,
            "replan {} vs plan-once {}",
            re.carbon_kg,
            once.carbon_kg
        );
    }

    #[test]
    fn blend_curve_sweep_covers_every_curve_and_completes() {
        let env = Env::small(120);
        let (rows, table) = blend_curves(&env);
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert_eq!(r.completed, 120, "{}", r.curve);
            assert_eq!(r.deadline_violations, 0, "{}", r.curve);
            assert!(r.carbon_kg > 0.0, "{}", r.curve);
            assert!(r.deferred > 0, "{}: blending must not stop deferral", r.curve);
        }
        let text = table.ascii();
        for curve in ["linear", "clamped_quadratic", "step"] {
            assert!(text.contains(curve), "missing {curve} row");
        }
        // the default the sweep argues for
        assert_eq!(BlendCurve::default(), BlendCurve::ClampedQuadratic);
        // deterministic like the other drift tables
        let (_, again) = blend_curves(&env);
        assert_eq!(table.ascii(), again.ascii());
    }

    #[test]
    fn drift_scenario_is_deterministic() {
        let env = Env::small(100);
        let (_, a) = drift(&env);
        let (_, b) = drift(&env);
        assert_eq!(a.ascii(), b.ascii());
    }

    #[test]
    fn sweep_is_deterministic() {
        let env = Env::small(120);
        let (_, a) = run(&env);
        let (_, b) = run(&env);
        assert_eq!(a.ascii(), b.ascii());
    }

    #[test]
    fn forecaster_scoreboard_ranks_structure_over_persistence() {
        let env = Env::small(10);
        let (results, table) = scores(&env);
        assert_eq!(results.len(), 4);
        assert_eq!(table.rows.len(), 4);
        let mape = |name: &str| {
            results.iter().find(|s| s.forecaster.contains(name)).unwrap().mape
        };
        // structure-aware models must beat flat persistence on a
        // diurnal signal, even with noise
        assert!(mape("seasonal") < mape("persistence"));
        assert!(mape("harmonic") < mape("persistence"));
    }
}
