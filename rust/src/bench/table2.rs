//! Table 2 reproduction: average inference metrics per (device, batch).
//!
//! The paper benchmarks 500 composite-corpus prompts on each device at
//! batch sizes 1/4/8 and reports averages of E2E latency, TTFT, TPOT,
//! token count, throughput, energy and carbon. We run the identical
//! protocol through the scheduler with an all-on-<device> strategy and
//! report per-request within-batch latencies (queue wait excluded, as
//! in the paper's offline benchmarking).

use crate::config::ExecutionMode;
use crate::coordinator::{run as run_sched, Grouping, PlacementPolicy, RunConfig};
use crate::report::{fmt, Table};

use super::Env;

/// One row of Table 2.
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub device: String,
    pub batch: usize,
    pub e2e_s: f64,
    pub ttft_s: f64,
    pub tpot_s: f64,
    pub tokens: f64,
    pub tps: f64,
    pub energy_kwh: f64,
    pub carbon_kg: f64,
    pub error_rate: f64,
}

/// Run the experiment and return (rows, rendered table).
pub fn run(env: &Env) -> (Vec<Table2Row>, Table) {
    let mut rows = Vec::new();
    for dev in &env.cluster.devices {
        for &batch in &[1usize, 4, 8] {
            let strategy = PlacementPolicy::spatial(&format!("all-on-{}", dev.name), &env.cluster)
                .expect("device strategy");
            let cfg = RunConfig {
                batch_size: batch,
                grouping: Grouping::Fifo,
                execution: ExecutionMode::Calibrated,
                max_new_tokens: env.cfg.serving.max_new_tokens,
                stochastic_seed: None,
                continuous_batching: false,
                ..RunConfig::default()
            };
            let r = run_sched(&env.cluster, &env.prompts, &strategy, &env.db, &cfg, None)
                .expect("table2 run");
            // within-batch latency: strip the closed-loop queue wait
            let n = r.metrics.len() as f64;
            let lat: f64 = r.metrics.iter().map(|m| m.e2e_s - m.queue_s).sum::<f64>() / n;
            let ttft: f64 = r.metrics.iter().map(|m| m.ttft_s - m.queue_s).sum::<f64>() / n;
            let tokens: f64 = r.metrics.iter().map(|m| m.output_tokens as f64).sum::<f64>() / n;
            let tps: f64 = r
                .metrics
                .iter()
                .map(|m| m.output_tokens as f64 / (m.e2e_s - m.queue_s).max(1e-9))
                .sum::<f64>()
                / n;
            rows.push(Table2Row {
                device: dev.name.clone(),
                batch,
                e2e_s: lat,
                ttft_s: ttft,
                tpot_s: r.overall.tpot.mean(),
                tokens,
                tps,
                energy_kwh: r.overall.energy_kwh.mean(),
                carbon_kg: r.overall.carbon_kg.mean(),
                error_rate: r.overall.error_rate(),
            });
        }
    }

    let mut table = Table::new(
        "table2",
        "Table 2 — average inference metrics per device and batch size (500 prompts)",
        &[
            "Hardware", "Batch", "E2E (s)", "TTFT (s)", "TPOT (s)", "Tokens",
            "Tokens/s", "Energy (kWh)", "Carbon (kgCO2e)", "Err",
        ],
    );
    for r in &rows {
        table.row(vec![
            r.device.clone(),
            r.batch.to_string(),
            fmt::secs(r.e2e_s),
            fmt::secs(r.ttft_s),
            format!("{:.3}", r.tpot_s),
            fmt::f2(r.tokens),
            fmt::f2(r.tps),
            fmt::sci(r.energy_kwh),
            fmt::sci(r.carbon_kg),
            fmt::pct(r.error_rate),
        ]);
    }
    table.note("per-prompt averages; queue wait excluded (offline benchmarking protocol)");
    (rows, table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::close;

    fn row<'a>(rows: &'a [Table2Row], dev: &str, b: usize) -> &'a Table2Row {
        rows.iter().find(|r| r.device.contains(dev) && r.batch == b).unwrap()
    }

    #[test]
    fn reproduces_table2_magnitudes() {
        // smaller corpus for speed; averages converge fast
        let env = Env::small(150);
        let (rows, _) = run(&env);
        assert_eq!(rows.len(), 6);

        // paper row anchors at batch 1 (tolerances cover corpus-mix noise)
        let j1 = row(&rows, "jetson", 1);
        close(j1.ttft_s, 0.36, 0.35).unwrap();
        assert!((8.0..20.0).contains(&j1.e2e_s), "jetson b1 e2e {}", j1.e2e_s);
        assert!((1e-5..4e-5).contains(&j1.energy_kwh), "jetson b1 kwh {}", j1.energy_kwh);

        let a1 = row(&rows, "ada", 1);
        assert!((2.0..6.0).contains(&a1.e2e_s), "ada b1 e2e {}", a1.e2e_s);
        assert!((4e-5..1.2e-4).contains(&a1.energy_kwh), "ada b1 kwh {}", a1.energy_kwh);

        // TTFT grows with batch on both devices (the paper's key cost of
        // batching)
        for dev in ["jetson", "ada"] {
            assert!(row(&rows, dev, 4).ttft_s > row(&rows, dev, 1).ttft_s, "{dev}");
            assert!(row(&rows, dev, 8).ttft_s > row(&rows, dev, 4).ttft_s, "{dev}");
        }
        // per-prompt energy falls from b1 to b4 (amortization)
        for dev in ["jetson", "ada"] {
            assert!(
                row(&rows, dev, 4).energy_kwh < row(&rows, dev, 1).energy_kwh,
                "{dev}"
            );
        }
        // 1B model more verbose than 12B (Table 2 token counts)
        assert!(j1.tokens > a1.tokens * 1.5);
        // jetson batch-8 instability: nonzero error rate, ada cleaner
        let j8 = row(&rows, "jetson", 8);
        let a8 = row(&rows, "ada", 8);
        assert!(j8.error_rate >= a8.error_rate);
        // carbon/energy ratio == grid intensity
        for r in &rows {
            close(r.carbon_kg / r.energy_kwh, 0.069, 1e-6).unwrap();
        }
    }

    #[test]
    fn table_renders_six_rows() {
        let env = Env::small(40);
        let (_, t) = run(&env);
        assert_eq!(t.rows.len(), 6);
    }
}
