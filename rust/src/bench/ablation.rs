//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. **estimator fidelity** — routing on the measured BenchmarkDb vs
//!    the analytic estimator vs a deliberately degraded DB (1 sample per
//!    cell): how much does the offline benchmarking phase buy?
//! 2. **batch grouping** — FIFO vs length-sorted batches (decode
//!    stragglers waste device occupancy);
//! 3. **complexity threshold** — sweep the complexity-aware strategy's
//!    CS cut-point.

use crate::config::ExecutionMode;
use crate::coordinator::{run as run_sched, BenchmarkDb, Grouping, PlacementPolicy, RunConfig};
use crate::report::{fmt, Table};

use super::Env;

/// One ablation result row.
#[derive(Debug, Clone)]
pub struct AblationRow {
    pub study: &'static str,
    pub variant: String,
    pub makespan_s: f64,
    pub total_carbon_kg: f64,
}

fn cfg(batch: usize, grouping: Grouping) -> RunConfig {
    RunConfig {
        batch_size: batch,
        grouping,
        execution: ExecutionMode::Calibrated,
        max_new_tokens: 96,
        stochastic_seed: None,
        continuous_batching: false,
        ..RunConfig::default()
    }
}

/// Run all ablation studies at batch 4.
pub fn run(env: &Env) -> (Vec<AblationRow>, Table) {
    let mut rows = Vec::new();

    // --- study 1: estimator fidelity --------------------------------
    // full DB (6 samples/cell, what Env::standard builds)
    let la = PlacementPolicy::spatial("latency-aware", &env.cluster).unwrap();
    let r = run_sched(&env.cluster, &env.prompts, &la, &env.db, &cfg(4, Grouping::Fifo), None)
        .unwrap();
    rows.push(AblationRow {
        study: "estimator",
        variant: "benchmark-db (6 samples/cell)".into(),
        makespan_s: r.makespan_s,
        total_carbon_kg: r.total_carbon_kg,
    });
    // degraded DB: a single noisy sample per cell
    let noisy = BenchmarkDb::build(&env.cluster, &[1, 4, 8], 1, 69.0, 0xBAD);
    let r = run_sched(&env.cluster, &env.prompts, &la, &noisy, &cfg(4, Grouping::Fifo), None)
        .unwrap();
    rows.push(AblationRow {
        study: "estimator",
        variant: "benchmark-db (1 sample/cell)".into(),
        makespan_s: r.makespan_s,
        total_carbon_kg: r.total_carbon_kg,
    });
    // analytic only: empty DB forces the fallback path
    let analytic = BenchmarkDb::build(&env.cluster, &[], 0, 69.0, 0);
    let r = run_sched(&env.cluster, &env.prompts, &la, &analytic, &cfg(4, Grouping::Fifo), None)
        .unwrap();
    rows.push(AblationRow {
        study: "estimator",
        variant: "analytic (no benchmarking)".into(),
        makespan_s: r.makespan_s,
        total_carbon_kg: r.total_carbon_kg,
    });

    // --- study 2: batch grouping ------------------------------------
    for (g, label) in [(Grouping::Fifo, "fifo"), (Grouping::LengthSorted, "length-sorted")] {
        let r = run_sched(&env.cluster, &env.prompts, &la, &env.db, &cfg(4, g), None)
            .unwrap();
        rows.push(AblationRow {
            study: "grouping",
            variant: label.into(),
            makespan_s: r.makespan_s,
            total_carbon_kg: r.total_carbon_kg,
        });
    }

    // --- study 3: complexity threshold ------------------------------
    for t in [0.1, 0.25, 0.35, 0.5, 0.7] {
        let s = PlacementPolicy::spatial(&format!("complexity-aware@{t}"), &env.cluster).unwrap();
        let r = run_sched(&env.cluster, &env.prompts, &s, &env.db, &cfg(4, Grouping::Fifo), None)
            .unwrap();
        rows.push(AblationRow {
            study: "cs-threshold",
            variant: format!("threshold {t}"),
            makespan_s: r.makespan_s,
            total_carbon_kg: r.total_carbon_kg,
        });
    }

    let mut table = Table::new(
        "ablation",
        "Ablations — estimator fidelity, batch grouping, complexity threshold (batch 4)",
        &["Study", "Variant", "Makespan (s)", "Total Carbon (kgCO2e)"],
    );
    for r in &rows {
        table.row(vec![
            r.study.to_string(),
            r.variant.clone(),
            fmt::secs(r.makespan_s),
            fmt::sci(r.total_carbon_kg),
        ]);
    }
    (rows, table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_studies_present() {
        let env = Env::small(80);
        let (rows, table) = run(&env);
        assert_eq!(rows.iter().filter(|r| r.study == "estimator").count(), 3);
        assert_eq!(rows.iter().filter(|r| r.study == "grouping").count(), 2);
        assert_eq!(rows.iter().filter(|r| r.study == "cs-threshold").count(), 5);
        assert_eq!(table.rows.len(), rows.len());
    }

    #[test]
    fn threshold_moves_the_tradeoff_monotonically_in_carbon() {
        // higher threshold -> more prompts "simple" -> more carbon-minimal
        // routing -> carbon falls (or holds), makespan rises (or holds)
        let env = Env::small(120);
        let (rows, _) = run(&env);
        let th: Vec<&AblationRow> =
            rows.iter().filter(|r| r.study == "cs-threshold").collect();
        for w in th.windows(2) {
            assert!(
                w[1].total_carbon_kg <= w[0].total_carbon_kg * 1.001,
                "{} -> {}",
                w[0].variant,
                w[1].variant
            );
        }
    }

    #[test]
    fn all_rows_positive() {
        let env = Env::small(60);
        let (rows, _) = run(&env);
        for r in &rows {
            assert!(r.makespan_s > 0.0 && r.total_carbon_kg > 0.0, "{r:?}");
        }
    }
}
