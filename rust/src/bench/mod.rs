//! Experiment drivers: one per paper table/figure + extensions.
//!
//! | driver | paper artefact |
//! |--------|----------------|
//! | [`fig1`] | Fig. 1 — IT/TTFT/TPS/TPOT for P1–P4 on Jetson-1B, Ada-12B, cloud |
//! | [`fig2`] | Fig. 2 — carbon + power for P1–P4 on both edge models |
//! | [`table2`] | Table 2 — per-device per-batch average inference metrics |
//! | [`table3`] | Table 3 — strategy comparison across batch 1/4/8 |
//! | [`sweep`] | §3 cross-batch analysis (TTFT↑, carbon/prompt↓, errors) |
//! | [`ablation`] | DESIGN.md ablations (estimator, grouping, threshold) |
//! | [`load`] | open-loop latency-vs-load sweep (serving extension) |
//! | [`shifting`] | temporal-shifting sweep: strategy × grid trace × deferrable fraction |
//! | [`scale`] | hot-path scale harness: decisions/sec at 1k/10k/100k prompts (perf trajectory) |
//! | [`churn`] | availability: strategy × outage scenario (failover vs shed, DES plane) |
//! | [`http`] | network fast path: loopback req/s by connections × keep-alive × streaming |
//!
//! [`harness`] is the in-tree micro-benchmark timer used by
//! `rust/benches/*` (criterion is not available offline).

pub mod ablation;
pub mod churn;
pub mod fig1;
pub mod fig2;
pub mod harness;
pub mod http;
pub mod load;
pub mod scale;
pub mod shifting;
pub mod sweep;
pub mod table2;
pub mod table3;

use crate::cluster::Cluster;
use crate::config::ExperimentConfig;
use crate::coordinator::BenchmarkDb;
use crate::workload::{trace, Corpus, Prompt};

/// Shared experiment environment built once per bench invocation.
pub struct Env {
    pub cfg: ExperimentConfig,
    pub cluster: Cluster,
    pub prompts: Vec<Prompt>,
    pub db: BenchmarkDb,
}

impl Env {
    /// Standard environment: the paper's 500-prompt closed-loop setup.
    pub fn standard() -> Self {
        Self::with_config(ExperimentConfig::default())
    }

    /// Environment from an explicit config.
    pub fn with_config(cfg: ExperimentConfig) -> Self {
        let cluster = Cluster::from_config(&cfg.cluster);
        let mut corpus = Corpus::generate(&cfg.workload);
        trace::assign_arrivals(&mut corpus.prompts, cfg.workload.arrival, cfg.workload.seed);
        let db = BenchmarkDb::build(
            &cluster,
            &[1, 4, 8],
            6,
            cfg.cluster.carbon_intensity_g_per_kwh,
            cfg.workload.seed ^ 0x0FF1_CE,
        );
        Env { cfg, cluster, prompts: corpus.prompts, db }
    }

    /// Smaller corpus for fast tests.
    pub fn small(prompts: usize) -> Self {
        let mut cfg = ExperimentConfig::default();
        cfg.workload.prompts = prompts;
        Self::with_config(cfg)
    }
}
