//! Fig. 2 reproduction: carbon footprint + power draw for P1–P4 on the
//! two edge models.
//!
//! The paper measures CO2eq and watts with JetPack/PyNVML while running
//! each canonical prompt on Gemma-3-1B (Jetson) and Gemma-3-12B (Ada).
//! Shape expectations (§2): the 1B model emits roughly one tenth of the
//! 12B's carbon on the reasoning prompts (P1, P2); both are low on the
//! factual ones (P3, P4); Ada draws ~60-70 W vs the Jetson's ~5 W.

use crate::cluster::{CarbonModel, DeviceProfile};
use crate::report::{fmt, Table};
use crate::simulator::{simulate_batch, BatchWork};
use crate::workload::canonical;

/// One measured bar of the figure.
#[derive(Debug, Clone)]
pub struct Fig2Point {
    pub prompt: &'static str,
    pub model: String,
    pub carbon_kg: f64,
    pub power_w: f64,
    pub energy_kwh: f64,
}

/// Run the experiment and return (points, rendered table).
pub fn run() -> (Vec<Fig2Point>, Table) {
    let carbon = CarbonModel::constant(69.0);
    let devices = [
        (DeviceProfile::jetson(), "Gemma3-1B-it (Jetson)"),
        (DeviceProfile::ada(), "Gemma3-12B-it (Ada)"),
    ];

    let mut points = Vec::new();
    for p in canonical::ALL {
        for (dev, label) in &devices {
            let out = p.to_prompt(0).output_tokens_on(dev.output_median_tokens);
            let work = BatchWork::new(vec![p.text.len()], vec![out]);
            let t = simulate_batch(dev, &work, None);
            points.push(Fig2Point {
                prompt: p.id,
                model: label.to_string(),
                carbon_kg: carbon.kg_co2e(t.energy_kwh, 0.0),
                power_w: t.energy_kwh * 3.6e6 / t.total_s,
                energy_kwh: t.energy_kwh,
            });
        }
    }

    let mut table = Table::new(
        "fig2",
        "Fig. 2 — carbon footprint and power draw, P1-P4 x {Gemma3-1B, Gemma3-12B}",
        &["prompt", "model", "carbon (kgCO2e)", "energy (kWh)", "power (W)"],
    );
    for pt in &points {
        table.row(vec![
            pt.prompt.to_string(),
            pt.model.clone(),
            fmt::sci(pt.carbon_kg),
            fmt::sci(pt.energy_kwh),
            fmt::f2(pt.power_w),
        ]);
    }
    table.note("batch size 1; 69 gCO2e/kWh grid intensity (back-derived from the paper)");
    (points, table)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point<'a>(pts: &'a [Fig2Point], prompt: &str, model: &str) -> &'a Fig2Point {
        pts.iter().find(|p| p.prompt == prompt && p.model.contains(model)).unwrap()
    }

    #[test]
    fn shape_matches_paper_figure() {
        let (pts, _) = run();
        assert_eq!(pts.len(), 8);

        // 1B emits far less than 12B on the reasoning prompts (paper:
        // "roughly one-tenth"); our calibration puts it in the 5-15x band
        for p in ["P1", "P2"] {
            let small = point(&pts, p, "1B");
            let big = point(&pts, p, "12B");
            let ratio = big.carbon_kg / small.carbon_kg;
            assert!((3.0..30.0).contains(&ratio), "{p}: ratio {ratio}");
        }
        // factual prompts are low-emission on both models
        for model in ["1B", "12B"] {
            let p4 = point(&pts, "P4", model);
            let p1 = point(&pts, "P1", model);
            assert!(p4.carbon_kg < p1.carbon_kg / 2.0, "{model}");
        }
        // power hierarchy: Jetson ~5 W, Ada ~60-70 W
        for p in ["P1", "P2", "P3", "P4"] {
            let j = point(&pts, p, "1B");
            let a = point(&pts, p, "12B");
            assert!((2.0..12.0).contains(&j.power_w), "jetson {}", j.power_w);
            assert!((40.0..80.0).contains(&a.power_w), "ada {}", a.power_w);
        }
        // carbon == energy x intensity
        for pt in &pts {
            assert!((pt.carbon_kg - pt.energy_kwh * 0.069).abs() < 1e-15);
        }
    }

    #[test]
    fn table_renders() {
        let (_, t) = run();
        assert_eq!(t.rows.len(), 8);
        assert!(t.ascii().contains("Gemma3-12B"));
    }
}
