//! Hot-path scale harness: `verdant bench scale`.
//!
//! Sweeps corpus sizes × routing strategies through the open-loop DES
//! and the closed-loop scheduler on a diurnal grid (half the corpus
//! deferrable), timing each whole run and reporting **decisions/sec**
//! — prompts placed per wall-clock second, end to end through the
//! plane. This is the perf trajectory every future PR measures itself
//! against: `--json` writes `BENCH_scale.json`, which CI archives per
//! PR.
//!
//! `forecast-carbon-aware` runs twice: with the per-step forecast memo
//! (the default) and with `memoize` off, the refit-every-decision path
//! this PR retired. The two rows make the cache's speedup — and, via
//! the identical `deferred` counts, its decision-equivalence — visible
//! in the same table. Decision equivalence is pinned bit-for-bit by
//! `tests/planes.rs`; this harness only has to prove the speed.
//!
//! With the engine swappable for the stub backend, the **wallclock
//! server** finally joins the table: `plane == "server"` rows run the
//! full threaded serving loop (`server::serve` under
//! `--execution stub`) over the 1k and 10k corpora with a heavily
//! compressed arrival replay — wall time, decisions/sec and deferrals
//! alongside the DES and closed-loop rows, so all three planes share
//! one perf trajectory. (100k is DES/closed-loop only: the wallclock
//! replay's real sleeps would dominate the measurement.)
//!
//! The sweep now reaches **one million prompts**: above
//! [`FULL_MATRIX_MAX_PROMPTS`] only the memoized DES rows run, plus a
//! sharded-accounting row (`Threads` column > 1) that fans the
//! bookkeeping over [`SHARDED_THREADS`] worker threads while making
//! bit-for-bit the same decisions — the CI bench gate holds the 1M
//! forecast-carbon-aware row's decisions/sec flat-or-better against
//! the 100k row.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cluster::{CarbonModel, Cluster};
use crate::config::{Arrival, ExecutionMode};
use crate::coordinator::online::{run_online, OnlineConfig};
use crate::coordinator::{run as run_sched, GridShiftConfig, PlacementPolicy, RunConfig};
use crate::grid::ForecastKind;
use crate::report::{fmt, Table};
use crate::server::{serve, ServeOptions};
use crate::util::stats::Histogram;
use crate::workload::{trace, Corpus, Prompt};

use super::Env;

/// Corpus sizes swept by `verdant bench scale` (`--max-prompts` caps
/// the sweep, e.g. for quick local runs).
pub const SCALE_COUNTS: [usize; 4] = [1_000, 10_000, 100_000, 1_000_000];

/// Largest corpus the full plane × variant matrix runs. Above it the
/// sweep keeps only the DES rows with memoized pricing (the hot path
/// the CI gate defends): the uncached variant refits the forecaster
/// per decision (~2M refits at 1M prompts) and the closed loop plans
/// per corpus — both would dominate the wall time without telling us
/// anything new about the per-decision path.
pub const FULL_MATRIX_MAX_PROMPTS: usize = 100_000;

/// Accounting shard threads for the extra sharded-DES row at the
/// million-prompt corpora (decisions stay bit-for-bit identical to the
/// single-thread row — pinned by `tests/planes.rs`; the row exists to
/// time the pipeline).
pub const SHARDED_THREADS: usize = 4;

/// Largest corpus the wallclock server rows run (the arrival replay is
/// real wall time even compressed; 100k would measure sleeping).
pub const SERVER_MAX_PROMPTS: usize = 10_000;

/// Virtual-seconds-per-wallclock-second compression for the server
/// rows. The ~28 h of virtual time (18 h arrival span + deferral
/// drain) replays as a fixed ~50 ms wall-time floor at this
/// compression — small against the 10k rows' scheduling work, but a
/// visible fraction of the 1k rows', so trend comparisons should lean
/// on the 10k server rows (the note on the table says so too).
pub const SERVER_TIME_SCALE: f64 = 2_000_000.0;

/// Arrival window the corpus is spread over (18 h of one day) and the
/// SLO marking, mirroring `bench shifting` so the planner has real
/// deferrable load to forecast for.
pub const ARRIVAL_SPAN_S: f64 = 18.0 * 3600.0;
pub const DEFER_FRAC: f64 = 0.5;
pub const DEADLINE_S: f64 = 10.0 * 3600.0;

/// One timed run.
#[derive(Debug, Clone)]
pub struct ScaleRow {
    /// Execution plane: "des" (open loop), "closed" (corpus plan) or
    /// "server" (the threaded wallclock loop on the stub backend).
    pub plane: &'static str,
    /// Strategy label (the uncached forecast variant is marked).
    pub strategy: String,
    pub prompts: usize,
    /// Accounting shard threads driving the DES run (1 = the inline,
    /// unsharded pipeline; always 1 on the other planes).
    pub threads: usize,
    pub wall_s: f64,
    /// Prompts placed per wall-clock second, whole-plane.
    pub decisions_per_s: f64,
    /// Prompts the policy shifted past arrival (equal between the
    /// cached and uncached forecast rows — the equivalence signal).
    pub deferred: usize,
    /// Per-decision latency percentiles in microseconds (one
    /// route-one + release-plan pass per prompt), measured for the
    /// on-arrival (DES) rows; `None` for the closed loop, whose
    /// decision is a whole-corpus plan rather than per-arrival.
    pub decide_p50_us: Option<f64>,
    pub decide_p95_us: Option<f64>,
    pub decide_p99_us: Option<f64>,
}

/// Sample size for the per-decision latency percentiles: enough for a
/// stable p99 while keeping the instrumented pass a small fraction of
/// the timed whole-plane run (at 100k prompts the uncached variant
/// would otherwise refit the forecaster another 200k times).
pub const PERCENTILE_SAMPLE: usize = 10_000;

/// Time the on-arrival decision path prompt by prompt: one
/// `route_arrival` + `plan_release` per prompt against an idle backlog
/// view, into a log-bucketed histogram (10 ns .. 10 s), over the first
/// [`PERCENTILE_SAMPLE`] prompts (arrival order — the same early trace
/// steps for every variant). This is the per-decision latency
/// distribution behind the DES rows' decisions/sec aggregate — the
/// tail (p99) is what the whole-plane number hides.
fn decision_percentiles(
    cluster: &Cluster,
    db: &crate::coordinator::BenchmarkDb,
    prompts: &[Prompt],
    strategy: &str,
    grid: Option<GridShiftConfig>,
    batch_size: usize,
) -> (f64, f64, f64) {
    let policy =
        PlacementPolicy::new(strategy, cluster, grid).expect("bench strategies resolve");
    let mut h = Histogram::new(1e-8, 10.0, 90);
    let backlog = vec![0.0; cluster.devices.len()];
    for p in &prompts[..prompts.len().min(PERCENTILE_SAMPLE)] {
        let t0 = Instant::now();
        let d = policy.route_arrival(p, cluster, db, batch_size, &backlog, p.arrival_s);
        let r = policy.plan_release(p, cluster, db, batch_size, 0.0, p.arrival_s);
        let dt = t0.elapsed().as_secs_f64();
        std::hint::black_box((d, r));
        h.add(dt);
    }
    (h.p50() * 1e6, h.p95() * 1e6, h.p99() * 1e6)
}

/// The strategy variants swept: label, strategy name, grid context.
fn variants(grid_trace: &crate::grid::GridTrace) -> Vec<(String, String, Option<GridShiftConfig>)> {
    vec![
        ("latency-aware".into(), "latency-aware".into(), None),
        ("carbon-aware".into(), "carbon-aware".into(), None),
        (
            "forecast-carbon-aware".into(),
            "forecast-carbon-aware".into(),
            Some(GridShiftConfig::new(grid_trace.clone(), ForecastKind::Harmonic)),
        ),
        (
            "forecast-carbon-aware (uncached)".into(),
            "forecast-carbon-aware".into(),
            Some(
                GridShiftConfig::new(grid_trace.clone(), ForecastKind::Harmonic)
                    .with_memoize(false),
            ),
        ),
    ]
}

/// Run the sweep over `counts` and return (rows, rendered table).
/// The CLI passes [`SCALE_COUNTS`]; tests pass smaller corpora.
pub fn run(env: &Env, counts: &[usize]) -> (Vec<ScaleRow>, Table) {
    let mut rows = Vec::new();
    let grid_trace = CarbonModel::diurnal(69.0, 0.3).to_trace(900.0);
    let mut cluster = Cluster::from_config(&env.cfg.cluster);
    cluster.carbon = CarbonModel::from_trace(grid_trace.clone()).into();

    for &n in counts {
        let mut wl = env.cfg.workload.clone();
        wl.prompts = n;
        let mut corpus = Corpus::generate(&wl);
        trace::assign_arrivals(
            &mut corpus.prompts,
            Arrival::Open { rate: n as f64 / ARRIVAL_SPAN_S },
            wl.seed,
        );
        trace::assign_slos(&mut corpus.prompts, DEFER_FRAC, DEADLINE_S, wl.seed ^ 0x51);
        let prompts = corpus.prompts;

        // one timed DES pass (`shards` > 1 drives the threaded
        // accounting pipeline; decisions are identical either way)
        let des_row = |label: &str,
                       strategy: &str,
                       grid: Option<GridShiftConfig>,
                       shards: usize,
                       rows: &mut Vec<ScaleRow>| {
            let cfg = OnlineConfig {
                strategy: strategy.to_string(),
                grid: grid.clone(),
                shards,
                // flight recorder explicitly off: these timed runs
                // measure the allocation-free disabled path the CI
                // bench gate defends
                trace: None,
                ..OnlineConfig::default()
            };
            let t0 = Instant::now();
            let r = run_online(&cluster, &prompts, &env.db, &cfg)
                .expect("bench strategies resolve");
            let wall = t0.elapsed().as_secs_f64();
            assert_eq!(r.completed, n, "DES dropped prompts");
            let (p50, p95, p99) = decision_percentiles(
                &cluster,
                &env.db,
                &prompts,
                strategy,
                grid,
                cfg.batch_size,
            );
            rows.push(ScaleRow {
                plane: "des",
                strategy: label.to_string(),
                prompts: n,
                threads: shards.max(1),
                wall_s: wall,
                decisions_per_s: n as f64 / wall.max(1e-9),
                deferred: r.deferred,
                decide_p50_us: Some(p50),
                decide_p95_us: Some(p95),
                decide_p99_us: Some(p99),
            });
        };

        // above FULL_MATRIX_MAX_PROMPTS only the memoized DES rows run
        // (plus the sharded pipeline row below) — see the const's doc
        let full = n <= FULL_MATRIX_MAX_PROMPTS;
        for (label, strategy, grid) in variants(&grid_trace) {
            if !full && label.ends_with("(uncached)") {
                continue;
            }
            // open-loop DES
            des_row(&label, &strategy, grid.clone(), 1, &mut rows);
            if !full {
                continue;
            }

            // closed-loop corpus plan + execution
            let policy = PlacementPolicy::new(&strategy, &cluster, grid.clone())
                .expect("bench strategies resolve");
            let t0 = Instant::now();
            let r = run_sched(&cluster, &prompts, &policy, &env.db, &RunConfig::default(), None)
                .expect("closed-loop run");
            let wall = t0.elapsed().as_secs_f64();
            assert_eq!(r.metrics.len(), n, "closed loop dropped prompts");
            rows.push(ScaleRow {
                plane: "closed",
                strategy: label.clone(),
                prompts: n,
                threads: 1,
                wall_s: wall,
                decisions_per_s: n as f64 / wall.max(1e-9),
                deferred: r.deferred,
                decide_p50_us: None,
                decide_p95_us: None,
                decide_p99_us: None,
            });

            // wallclock server on the stub backend: the whole threaded
            // loop (ingest + per-device workers + collector), arrival
            // replay compressed hard so scheduling is the measured work
            if n <= SERVER_MAX_PROMPTS {
                let opts = ServeOptions::builder()
                    .cluster(&cluster)
                    .batch_size(4)
                    .batch_timeout(Duration::from_millis(5))
                    .max_new_tokens(8)
                    .time_scale(SERVER_TIME_SCALE)
                    .strategy(strategy.clone())
                    .grid(grid)
                    .execution(ExecutionMode::Stub)
                    .db(Some(Arc::new(env.db.clone())))
                    .trace(None) // disabled path, same as the DES rows
                    .build()
                    .expect("bench serve options validate");
                let t0 = Instant::now();
                let r = serve(&cluster, &prompts, &opts).expect("stub serve");
                let wall = t0.elapsed().as_secs_f64();
                assert_eq!(r.completed, n, "server dropped prompts");
                rows.push(ScaleRow {
                    plane: "server",
                    strategy: label,
                    prompts: n,
                    threads: 1,
                    wall_s: wall,
                    decisions_per_s: n as f64 / wall.max(1e-9),
                    deferred: r.deferred,
                    decide_p50_us: None,
                    decide_p95_us: None,
                    decide_p99_us: None,
                });
            }
        }

        // the sharded accounting pipeline at the corpora it exists
        // for: same decisions as the threads=1 row above, bookkeeping
        // fanned out over SHARDED_THREADS worker threads
        if !full {
            let (_, strategy, grid) = variants(&grid_trace).swap_remove(2);
            des_row(
                &format!("forecast-carbon-aware (sharded x{SHARDED_THREADS})"),
                &strategy,
                grid,
                SHARDED_THREADS,
                &mut rows,
            );
        }
    }

    let mut table = Table::new(
        "BENCH_scale",
        "Hot-path scale — decisions/sec by plane × strategy × corpus size",
        &["Plane", "Strategy", "Prompts", "Threads", "Wall (s)", "Decisions/s", "Deferred",
          "Decide p50 (us)", "Decide p95 (us)", "Decide p99 (us)"],
    );
    let us = |x: Option<f64>| x.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into());
    for r in &rows {
        table.row(vec![
            r.plane.to_string(),
            r.strategy.clone(),
            r.prompts.to_string(),
            r.threads.to_string(),
            fmt::secs(r.wall_s),
            format!("{:.0}", r.decisions_per_s),
            r.deferred.to_string(),
            us(r.decide_p50_us),
            us(r.decide_p95_us),
            us(r.decide_p99_us),
        ]);
    }
    table.note(format!(
        "arrivals over {:.0} h, {:.0}% deferrable (deadline {:.0} h), diurnal grid, \
         harmonic forecaster; decisions/s = prompts / whole-plane wall time; the \
         (uncached) rows refit the forecaster per decision — the pre-memoization \
         hot path, decision-identical by tests/planes.rs; decide percentiles time \
         one route-one + release-plan pass per prompt over the first {} prompts \
         (DES rows only — the closed loop plans per corpus, not per arrival); \
         server rows run the threaded wallclock loop on the stub backend at \
         {:.0}x time compression (<= {} prompts — the replay is real wall time \
         with a fixed ~50 ms floor, so compare server trends on the 10k rows; \
         the 1k rows are partly replay-bound), their decisions/s includes \
         thread handoff + queueing, and their deferral counts see live \
         wallclock backlog rather than the DES's virtual-time backlog; above \
         {} prompts only the memoized DES rows run, plus a sharded-pipeline row \
         (Threads > 1) whose decisions are bit-for-bit the Threads=1 row's",
        ARRIVAL_SPAN_S / 3600.0,
        DEFER_FRAC * 100.0,
        DEADLINE_S / 3600.0,
        PERCENTILE_SAMPLE,
        SERVER_TIME_SCALE,
        SERVER_MAX_PROMPTS,
        FULL_MATRIX_MAX_PROMPTS
    ));
    (rows, table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_rows_cover_all_three_planes_and_agree_on_deferrals() {
        let env = Env::small(40);
        let (rows, table) = run(&env, &[60]);
        // 3 planes × 4 strategy variants (60 <= SERVER_MAX_PROMPTS;
        // the sharded row only appears above FULL_MATRIX_MAX_PROMPTS)
        assert_eq!(rows.len(), 12);
        assert!(table.ascii().contains("forecast-carbon-aware (uncached)"));
        assert!(table.ascii().contains("Threads"));
        assert!(rows.iter().all(|r| r.threads == 1), "small corpora stay unsharded");
        // the CI gate's 1M flat-or-better check needs these in the sweep
        assert!(SCALE_COUNTS.contains(&100_000) && SCALE_COUNTS.contains(&1_000_000));
        assert_eq!(
            rows.iter().filter(|r| r.plane == "server").count(),
            4,
            "every strategy variant needs a server-plane row"
        );
        for r in &rows {
            assert!(r.wall_s >= 0.0);
            assert!(r.decisions_per_s > 0.0, "{}/{}", r.plane, r.strategy);
            assert_eq!(r.prompts, 60);
            // per-decision percentiles: present, ordered and positive
            // on the on-arrival plane; absent on the corpus plane
            match r.plane {
                "des" => {
                    let (p50, p95, p99) = (
                        r.decide_p50_us.unwrap(),
                        r.decide_p95_us.unwrap(),
                        r.decide_p99_us.unwrap(),
                    );
                    assert!(p50 > 0.0, "{}: p50 {p50}", r.strategy);
                    assert!(p50 <= p95 + 1e-9 && p95 <= p99 + 1e-9, "{}", r.strategy);
                }
                _ => assert!(r.decide_p50_us.is_none()),
            }
        }
        assert!(table.ascii().contains("Decide p50 (us)"));
        // the memo must be decision-invisible: identical deferral
        // counts between the cached and uncached forecast rows
        for plane in ["des", "closed"] {
            let cached = rows
                .iter()
                .find(|r| r.plane == plane && r.strategy == "forecast-carbon-aware")
                .unwrap();
            let uncached = rows
                .iter()
                .find(|r| r.plane == plane && r.strategy == "forecast-carbon-aware (uncached)")
                .unwrap();
            assert_eq!(cached.deferred, uncached.deferred, "{plane}");
            assert!(cached.deferred > 0, "{plane}: scenario must defer something");
        }
    }
}
