//! TOML-subset parser (toml-crate substitute, offline build).
//!
//! Supports the full surface our config files use:
//! - `[table]` and dotted `[table.sub]` headers
//! - `[[array-of-tables]]`
//! - `key = value` with bare or quoted keys, dotted keys
//! - values: basic strings, integers, floats (incl. scientific), bools,
//!   inline arrays `[1, 2, 3]`, inline tables `{a = 1}`
//! - `#` comments, blank lines
//!
//! Unsupported (and rejected loudly rather than mis-parsed): multi-line
//! strings, literal strings ('..'), dates.
//!
//! Output reuses [`crate::util::json::Value`] so downstream typed-config
//! code shares one value model with the JSON manifest.

use crate::util::json::Value;
use std::collections::BTreeMap;

/// Parse error with line number.
#[derive(Debug, Clone)]
pub struct TomlError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.message)
    }
}
impl std::error::Error for TomlError {}

/// Parse a TOML document into a Value::Obj tree.
pub fn parse(input: &str) -> Result<Value, TomlError> {
    let mut root = BTreeMap::new();
    // Current insertion path ([table] header); empty = root.
    let mut current_path: Vec<String> = Vec::new();

    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        let errl = |msg: &str| TomlError { line: lineno + 1, message: msg.to_string() };
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("[[") {
            let name = rest.strip_suffix("]]").ok_or_else(|| errl("unterminated [[header]]"))?;
            let path = parse_key_path(name).map_err(|m| errl(&m))?;
            push_array_table(&mut root, &path).map_err(|m| errl(&m))?;
            current_path = path;
        } else if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| errl("unterminated [header]"))?;
            let path = parse_key_path(name).map_err(|m| errl(&m))?;
            ensure_table(&mut root, &path).map_err(|m| errl(&m))?;
            current_path = path;
        } else {
            let eq = find_unquoted(line, '=').ok_or_else(|| errl("expected key = value"))?;
            let key_part = line[..eq].trim();
            let val_part = line[eq + 1..].trim();
            if val_part.is_empty() {
                return Err(errl("missing value"));
            }
            let mut path = current_path.clone();
            path.extend(parse_key_path(key_part).map_err(|m| errl(&m))?);
            let value = parse_value(val_part).map_err(|m| errl(&m))?;
            insert(&mut root, &path, value).map_err(|m| errl(&m))?;
        }
    }
    Ok(Value::Obj(root))
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn find_unquoted(s: &str, target: char) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            c if c == target && !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn parse_key_path(s: &str) -> Result<Vec<String>, String> {
    let mut out = Vec::new();
    for part in split_dotted(s)? {
        let part = part.trim();
        let key = if let Some(q) = part.strip_prefix('"') {
            q.strip_suffix('"').ok_or("unterminated quoted key")?.to_string()
        } else {
            if part.is_empty()
                || !part.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
            {
                return Err(format!("invalid bare key '{part}'"));
            }
            part.to_string()
        };
        out.push(key);
    }
    Ok(out)
}

fn split_dotted(s: &str) -> Result<Vec<&str>, String> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '.' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if in_str {
        return Err("unterminated string in key".into());
    }
    parts.push(&s[start..]);
    Ok(parts)
}

fn parse_value(s: &str) -> Result<Value, String> {
    let s = s.trim();
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or("unterminated string")?;
        return unescape(body);
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') {
        let inner = s.strip_prefix('[').unwrap().strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        for piece in split_top_level(inner, ',') {
            let piece = piece.trim();
            if piece.is_empty() {
                continue;
            }
            items.push(parse_value(piece)?);
        }
        return Ok(Value::Arr(items));
    }
    if s.starts_with('{') {
        let inner = s.strip_prefix('{').unwrap().strip_suffix('}').ok_or("unterminated inline table")?;
        let mut map = BTreeMap::new();
        for piece in split_top_level(inner, ',') {
            let piece = piece.trim();
            if piece.is_empty() {
                continue;
            }
            let eq = find_unquoted(piece, '=').ok_or("expected k = v in inline table")?;
            let keys = parse_key_path(piece[..eq].trim())?;
            if keys.len() != 1 {
                return Err("dotted keys unsupported in inline tables".into());
            }
            map.insert(keys[0].clone(), parse_value(piece[eq + 1..].trim())?);
        }
        return Ok(Value::Obj(map));
    }
    if s.starts_with('\'') {
        return Err("literal strings ('...') unsupported".into());
    }
    // number: allow underscores
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    cleaned
        .parse::<f64>()
        .map(Value::Num)
        .map_err(|_| format!("cannot parse value '{s}'"))
}

fn split_top_level(s: &str, sep: char) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' | '{' if !in_str => depth += 1,
            ']' | '}' if !in_str => depth -= 1,
            c if c == sep && depth == 0 && !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

fn unescape(s: &str) -> Result<Value, String> {
    let mut out = String::new();
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('r') => out.push('\r'),
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            Some(c) => return Err(format!("unsupported escape \\{c}")),
            None => return Err("dangling backslash".into()),
        }
    }
    Ok(Value::Str(out))
}

fn ensure_table<'a>(
    root: &'a mut BTreeMap<String, Value>,
    path: &[String],
) -> Result<&'a mut BTreeMap<String, Value>, String> {
    let mut cur = root;
    for key in path {
        let entry = cur
            .entry(key.clone())
            .or_insert_with(|| Value::Obj(BTreeMap::new()));
        cur = match entry {
            Value::Obj(m) => m,
            Value::Arr(items) => match items.last_mut() {
                Some(Value::Obj(m)) => m,
                _ => return Err(format!("'{key}' is not a table")),
            },
            _ => return Err(format!("'{key}' already a non-table value")),
        };
    }
    Ok(cur)
}

fn push_array_table(root: &mut BTreeMap<String, Value>, path: &[String]) -> Result<(), String> {
    let (last, parents) = path.split_last().ok_or("empty [[header]]")?;
    let parent = ensure_table(root, parents)?;
    let entry = parent.entry(last.clone()).or_insert_with(|| Value::Arr(Vec::new()));
    match entry {
        Value::Arr(items) => {
            items.push(Value::Obj(BTreeMap::new()));
            Ok(())
        }
        _ => Err(format!("'{last}' already a non-array value")),
    }
}

fn insert(root: &mut BTreeMap<String, Value>, path: &[String], value: Value) -> Result<(), String> {
    let (last, parents) = path.split_last().ok_or("empty key")?;
    let parent = ensure_table(root, parents)?;
    if parent.contains_key(last) {
        return Err(format!("duplicate key '{last}'"));
    }
    parent.insert(last.clone(), value);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_tables_and_arrays() {
        let doc = r#"
# top comment
title = "verdant"   # trailing comment
count = 500
ratio = 6.35e-5
flag = true
batch_sizes = [1, 4, 8]

[cluster]
name = "edge-lab"
carbon_intensity = 69.0

[cluster.site]
region = "AT"
"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("title").unwrap().as_str(), Some("verdant"));
        assert_eq!(v.get("count").unwrap().as_f64(), Some(500.0));
        assert_eq!(v.get("ratio").unwrap().as_f64(), Some(6.35e-5));
        assert_eq!(v.get("flag").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("batch_sizes").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.path(&["cluster", "name"]).unwrap().as_str(), Some("edge-lab"));
        assert_eq!(v.path(&["cluster", "site", "region"]).unwrap().as_str(), Some("AT"));
    }

    #[test]
    fn array_of_tables() {
        let doc = r#"
[[device]]
name = "jetson"
mem = 8

[[device]]
name = "ada"
mem = 16
sub = { a = 1, b = "x" }
"#;
        let v = parse(doc).unwrap();
        let devs = v.get("device").unwrap().as_arr().unwrap();
        assert_eq!(devs.len(), 2);
        assert_eq!(devs[0].get("name").unwrap().as_str(), Some("jetson"));
        assert_eq!(devs[1].path(&["sub", "a"]).unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn keys_after_array_table_attach_to_last_element() {
        let doc = "[[d]]\nx = 1\n[[d]]\nx = 2\n[d.inner]\ny = 3\n";
        let v = parse(doc).unwrap();
        let d = v.get("d").unwrap().as_arr().unwrap();
        assert_eq!(d[0].get("x").unwrap().as_f64(), Some(1.0));
        assert_eq!(d[1].path(&["inner", "y"]).unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn dotted_and_quoted_keys() {
        let v = parse("a.b.c = 1\n\"weird key\" = 2\n").unwrap();
        assert_eq!(v.path(&["a", "b", "c"]).unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("weird key").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn string_escapes_and_hash_inside_string() {
        let v = parse(r#"s = "a # not comment \n\"q\"" "#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("a # not comment \n\"q\""));
    }

    #[test]
    fn numbers_with_underscores() {
        let v = parse("n = 1_000_000\n").unwrap();
        assert_eq!(v.get("n").unwrap().as_f64(), Some(1e6));
    }

    #[test]
    fn errors_with_line_numbers() {
        let e = parse("ok = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(parse("k = 'lit'").is_err());
        assert!(parse("[unclosed\n").is_err());
        assert!(parse("a = 1\na = 2\n").is_err()); // duplicate
        assert!(parse("k = \n").is_err());
    }

    #[test]
    fn observability_table_parses_like_any_other() {
        // the `[observability]` section the flight recorder reads is
        // plain string keys — make sure paths with dots/slashes survive
        let doc = "[observability]\ntrace = \"out/run.jsonl\"\nmetrics_json = \"m.json\"\n";
        let v = parse(doc).unwrap();
        assert_eq!(v.path(&["observability", "trace"]).unwrap().as_str(), Some("out/run.jsonl"));
        assert_eq!(v.path(&["observability", "metrics_json"]).unwrap().as_str(), Some("m.json"));
    }

    #[test]
    fn nested_inline_arrays() {
        let v = parse("m = [[1, 2], [3, 4]]\n").unwrap();
        let outer = v.get("m").unwrap().as_arr().unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[1].as_arr().unwrap()[0].as_f64(), Some(3.0));
    }
}
