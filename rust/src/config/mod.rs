//! Typed configuration system (TOML files -> validated structs).
//!
//! One `ExperimentConfig` drives everything: the cluster topology
//! (devices + optional cloud point), the workload (corpus size, seed,
//! arrival process), and serving parameters (batch size, strategy,
//! execution mode). `configs/cluster.toml` ships the paper's testbed;
//! every CLI subcommand accepts `--config <path>` plus flag overrides.

pub mod toml;

use crate::util::json::Value;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// How batches are executed (DESIGN.md §Real-vs-calibrated-clock).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutionMode {
    /// Run the AOT artifacts through PJRT for real token generation AND
    /// use the calibrated device model for time/energy.
    Real,
    /// Skip PJRT; sample output token counts from the workload model.
    /// Time/energy from the calibrated device model. Used for the
    /// 500-prompt paper tables (fast, deterministic).
    Calibrated,
    /// PJRT for a deterministic subset of batches (spot-check), sampled
    /// token counts for the rest.
    Hybrid,
    /// No PJRT anywhere: token generation through the deterministic
    /// `runtime::CalibratedBackend` stub, time/energy from the
    /// calibrated clock (the Hybrid timing rule). Needs no artifacts —
    /// the mode that lets the wallclock server run in CI and in
    /// `bench scale`.
    Stub,
}

impl ExecutionMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "real" => Ok(Self::Real),
            "calibrated" => Ok(Self::Calibrated),
            "hybrid" => Ok(Self::Hybrid),
            "stub" => Ok(Self::Stub),
            _ => bail!("unknown execution mode '{s}' (real|calibrated|hybrid|stub)"),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Self::Real => "real",
            Self::Calibrated => "calibrated",
            Self::Hybrid => "hybrid",
            Self::Stub => "stub",
        }
    }
}

/// Calibration profile family for a device (which anchor table to use).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceKind {
    /// NVIDIA Jetson Orin NX 8 GB serving the 1B-class model.
    Jetson,
    /// NVIDIA Ada 2000 16 GB serving the 12B-class model.
    Ada,
    /// Cloud API endpoint (Gemini-2.0-Flash-like) behind a network link.
    Cloud,
}

impl DeviceKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "jetson" => Ok(Self::Jetson),
            "ada" => Ok(Self::Ada),
            "cloud" => Ok(Self::Cloud),
            _ => bail!("unknown device kind '{s}' (jetson|ada|cloud)"),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Self::Jetson => "jetson",
            Self::Ada => "ada",
            Self::Cloud => "cloud",
        }
    }
}

/// One device entry in the cluster.
#[derive(Debug, Clone)]
pub struct DeviceConfig {
    pub name: String,
    pub kind: DeviceKind,
    /// GPU memory capacity in GB (drives admission + saturation model).
    pub gpu_mem_gb: f64,
    /// Artifact variant served by this device (manifest key).
    pub model: String,
}

/// Cloud API point (used by the Fig. 1 motivation experiment).
#[derive(Debug, Clone)]
pub struct CloudConfig {
    pub enabled: bool,
    pub rtt_ms: f64,
    pub bandwidth_mbps: f64,
}

/// Grid carbon-intensity model, as expressed in the `[cluster.carbon]`
/// TOML table (`cluster::Cluster::from_config` instantiates it).
#[derive(Debug, Clone, PartialEq)]
pub enum CarbonModelConfig {
    /// `model = "constant"` — fixed gCO2e/kWh (the paper's setting).
    Constant { g_per_kwh: f64 },
    /// `model = "diurnal"` — duck curve around a mean with fractional
    /// swing (interpolated hourly anchors).
    Diurnal { mean_g_per_kwh: f64, swing: f64 },
    /// `model = "trace"` — explicit samples on a fixed step, extended
    /// periodically.
    Trace { step_s: f64, samples: Vec<f64> },
    /// `model = "synthetic"` — seeded diurnal + weekly + AR(1)-noise
    /// generator (see `grid::SyntheticTrace`).
    Synthetic {
        mean_g_per_kwh: f64,
        swing: f64,
        weekly_swing: f64,
        noise: f64,
        days: usize,
        step_s: f64,
        seed: u64,
    },
}

impl CarbonModelConfig {
    /// Mean intensity implied by the model (drives the benchmark DB's
    /// scalar carbon estimates).
    pub fn mean_g_per_kwh(&self) -> f64 {
        match self {
            CarbonModelConfig::Constant { g_per_kwh } => *g_per_kwh,
            CarbonModelConfig::Diurnal { mean_g_per_kwh, .. }
            | CarbonModelConfig::Synthetic { mean_g_per_kwh, .. } => *mean_g_per_kwh,
            CarbonModelConfig::Trace { samples, .. } => {
                if samples.is_empty() {
                    0.0
                } else {
                    samples.iter().sum::<f64>() / samples.len() as f64
                }
            }
        }
    }
}

/// Cluster topology + grid carbon intensity.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub name: String,
    /// Grid carbon intensity in gCO2e per kWh. 69 g/kWh back-derived
    /// from the paper's Table 2 (4.38e-6 kg / 6.35e-5 kWh). Kept as the
    /// scalar the routing estimates use; `carbon` is the full model.
    pub carbon_intensity_g_per_kwh: f64,
    /// Time-resolved carbon model (defaults to constant at the scalar).
    pub carbon: CarbonModelConfig,
    pub devices: Vec<DeviceConfig>,
    pub cloud: CloudConfig,
}

/// Arrival process for the request trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrival {
    /// All prompts queued at t=0 (the paper's batch-evaluation setup).
    Closed,
    /// Poisson arrivals at `rate` req/s (serving extension experiments).
    Open { rate: f64 },
}

/// Workload generation parameters.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// Number of prompts sampled from the composite corpus (paper: 500).
    pub prompts: usize,
    pub seed: u64,
    /// Restrict to named categories; empty = all eight.
    pub categories: Vec<String>,
    pub arrival: Arrival,
}

/// Serving-loop parameters.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Prompts per inference pass (paper sweeps 1/4/8).
    pub batch_size: usize,
    /// Max time the batcher waits to fill a batch (open-loop arrivals).
    pub batch_timeout_ms: f64,
    /// Routing strategy name, resolved by `coordinator::router::build`.
    pub strategy: String,
    pub execution: ExecutionMode,
    /// Generation cap per request (must fit max_seq - prefill_len).
    pub max_new_tokens: usize,
    /// Fraction of the workload marked `Deferrable` (0 = every prompt
    /// `Interactive`, the paper's setting).
    pub deferrable_frac: f64,
    /// Completion deadline for `Deferrable` prompts, seconds.
    pub deferrable_deadline_s: f64,
    /// Hold `Deferrable` prompts for forecast clean windows (only
    /// effective with a time-varying `[cluster.carbon]` model).
    pub defer: bool,
    /// Carbon-aware batch sizing: a free device holding only a partial
    /// batch of `Deferrable` prompts may wait for a cleaner window.
    pub carbon_sizing: bool,
    /// Receding-horizon re-planning of held work: re-plan deferral
    /// releases and sizing holds when the forecast drifts from the
    /// realized trace or on the fixed cadence below. Off by default —
    /// plan-once, bit-for-bit the pre-replan behaviour.
    pub replan: bool,
    /// Fixed replan cadence, seconds.
    pub replan_interval_s: f64,
    /// Rolling realized-vs-forecast MAPE that declares the active
    /// forecast wrong (fraction, e.g. 0.2 = 20 %).
    pub drift_threshold: f64,
    /// Drift-aware forecast *blending*: discount the fitted forecast
    /// toward persistence proportionally to the rolling MAPE (full
    /// persistence at `drift_threshold`) instead of the binary
    /// trust/distrust replan trigger. Off by default — planning is
    /// bit-for-bit the pure-fit behaviour.
    pub blend: bool,
    /// Hybrid execution: re-audit every Nth batch per artifact variant
    /// through PJRT (0 = legacy first-batch-only spot-check).
    pub spot_check_every_n: usize,
    /// Continuous batching: late arrivals join a compatible in-flight
    /// partial batch at decode boundaries, on all three planes. Off by
    /// default — execution is bit-for-bit the fixed-batch behaviour.
    pub continuous_batching: bool,
    /// OOM-retry / failover budget (`[serving.failure]`). The default
    /// reproduces the historic constants bit-for-bit.
    pub failure: crate::simulator::FailurePolicy,
    /// Device-churn timeline (`[serving.churn]`). Empty by default —
    /// no churn machinery anywhere, bit-for-bit the pre-churn paths.
    pub churn: ChurnConfig,
    /// Network front-end (`[serving.http]`), used by `serve --http`.
    pub http: HttpConfig,
}

/// `[serving.http]` — the OpenAI-compatible network front-end.
/// Only consulted when `serve --http` is on; the defaults serve
/// loopback with a bounded queue.
#[derive(Debug, Clone, PartialEq)]
pub struct HttpConfig {
    /// Bind address, `host:port` (port 0 = ephemeral, for tests).
    pub addr: String,
    /// Admission bound: requests beyond this many queued-or-running
    /// are shed with HTTP 429.
    pub max_queue_depth: usize,
    /// Non-streaming requests time out with HTTP 504 after this long.
    pub request_timeout_s: f64,
    /// Connection worker threads; `0` = auto (2×available cores).
    pub conn_workers: usize,
    /// Kept-alive connections idle this long are closed.
    pub idle_timeout_s: f64,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            addr: "127.0.0.1:8080".into(),
            max_queue_depth: 256,
            request_timeout_s: 30.0,
            conn_workers: 0,
            idle_timeout_s: 5.0,
        }
    }
}

/// `[serving.churn]` — device availability for churn experiments.
/// Either scripted outage windows or a stochastic MTBF/MTTR model;
/// the empty default disables churn entirely.
#[derive(Debug, Clone, PartialEq)]
pub struct ChurnConfig {
    /// Scripted outage windows, `"device:start_s:end_s"` each
    /// (device = index into the cluster's device list, times in
    /// virtual seconds). Mutually exclusive with `mtbf_s`/`mttr_s`.
    pub outages: Vec<String>,
    /// Stochastic model: mean up-time between failures, seconds.
    pub mtbf_s: Option<f64>,
    /// Stochastic model: mean repair time, seconds.
    pub mttr_s: Option<f64>,
    /// Stochastic horizon — new failures start before this, seconds.
    pub horizon_s: f64,
    /// Seed for the stochastic schedule sampler.
    pub seed: u64,
    /// Devices report Degraded this long before each outage, seconds.
    pub degraded_lead_s: f64,
    /// Devices report Recovering this long after each outage, seconds.
    pub recovering_tail_s: f64,
}

impl Default for ChurnConfig {
    fn default() -> Self {
        ChurnConfig {
            outages: Vec::new(),
            mtbf_s: None,
            mttr_s: None,
            horizon_s: 3600.0,
            seed: 42,
            degraded_lead_s: 0.0,
            recovering_tail_s: 0.0,
        }
    }
}

impl ChurnConfig {
    /// True when the table asks for any churn at all.
    pub fn is_enabled(&self) -> bool {
        !self.outages.is_empty() || self.mtbf_s.is_some() || self.mttr_s.is_some()
    }

    /// Field-level invariants (spec syntax, non-negative intervals).
    /// Cross-cluster checks (device bounds) live in [`Self::to_schedule`],
    /// which knows the cluster size.
    pub fn validate(&self) -> Result<()> {
        if !self.outages.is_empty() && (self.mtbf_s.is_some() || self.mttr_s.is_some()) {
            bail!(
                "[serving.churn] scripted outages and the stochastic \
                 mtbf_s/mttr_s model are mutually exclusive"
            );
        }
        if self.mtbf_s.is_some() != self.mttr_s.is_some() {
            bail!("[serving.churn] stochastic churn needs both mtbf_s and mttr_s");
        }
        if !self.outages.is_empty() {
            // full scripted-schedule validation (syntax, reversed or
            // overlapping windows); device bounds wait for the cluster
            let windows = self
                .outages
                .iter()
                .map(|s| crate::simulator::OutageWindow::parse(s))
                .collect::<Result<Vec<_>>>()?;
            crate::simulator::ChurnSchedule::scripted(windows)?;
        }
        if !(self.horizon_s > 0.0 && self.horizon_s.is_finite()) {
            bail!("[serving.churn] horizon_s must be positive and finite, got {}", self.horizon_s);
        }
        for (x, what) in [
            (self.degraded_lead_s, "degraded_lead_s"),
            (self.recovering_tail_s, "recovering_tail_s"),
        ] {
            if !(x >= 0.0 && x.is_finite()) {
                bail!("[serving.churn] {what} must be >= 0 and finite, got {x}");
            }
        }
        Ok(())
    }

    /// Materialize the schedule for an `n_devices` cluster. `None`
    /// when churn is off — the bit-for-bit default path for every
    /// plane.
    pub fn to_schedule(&self, n_devices: usize) -> Result<Option<crate::simulator::ChurnSchedule>> {
        use crate::simulator::{ChurnSchedule, OutageWindow};
        self.validate()?;
        if !self.is_enabled() {
            return Ok(None);
        }
        let schedule = if !self.outages.is_empty() {
            let windows = self
                .outages
                .iter()
                .map(|s| OutageWindow::parse(s))
                .collect::<Result<Vec<_>>>()?;
            ChurnSchedule::scripted(windows)?
        } else {
            // validate() guarantees both halves are present here
            let (mtbf, mttr) = (self.mtbf_s.unwrap(), self.mttr_s.unwrap());
            let mut rng = crate::util::rng::Rng::new(self.seed);
            ChurnSchedule::stochastic(n_devices, mtbf, mttr, self.horizon_s, &mut rng)?
        };
        if let Some(md) = schedule.max_device() {
            if md >= n_devices {
                bail!("[serving.churn] names device {md}, cluster has {n_devices} devices");
            }
        }
        Ok(Some(
            schedule
                .with_degraded_lead_s(self.degraded_lead_s)
                .with_recovering_tail_s(self.recovering_tail_s),
        ))
    }
}

/// Flight-recorder / metrics-registry knobs (`[observability]` table;
/// the `--trace` / `--metrics-json` CLI flags override both paths).
#[derive(Debug, Clone, Default)]
pub struct ObservabilityConfig {
    /// Write one JSONL trace event per scheduling decision here.
    /// `None` (the default) disables tracing entirely — the decision
    /// hot path never allocates an event.
    pub trace: Option<String>,
    /// Dump the end-of-run metrics-registry snapshot as JSON here.
    pub metrics_json: Option<String>,
}

/// Top-level experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub cluster: ClusterConfig,
    pub workload: WorkloadConfig,
    pub serving: ServingConfig,
    pub observability: ObservabilityConfig,
    /// Directory containing manifest.json + HLO artifacts.
    pub artifacts_dir: String,
}

impl Default for ExperimentConfig {
    /// The paper's testbed: Jetson Orin NX 8 GB + Ada 2000 16 GB,
    /// Austrian grid intensity, 500 prompts, batch 4, latency-aware.
    fn default() -> Self {
        Self {
            cluster: ClusterConfig {
                name: "edge-lab".into(),
                carbon_intensity_g_per_kwh: 69.0,
                carbon: CarbonModelConfig::Constant { g_per_kwh: 69.0 },
                devices: vec![
                    DeviceConfig {
                        name: "jetson-orin-nx".into(),
                        kind: DeviceKind::Jetson,
                        gpu_mem_gb: 8.0,
                        model: "edge-1b-sim".into(),
                    },
                    DeviceConfig {
                        name: "ada-2000".into(),
                        kind: DeviceKind::Ada,
                        gpu_mem_gb: 16.0,
                        model: "edge-12b-sim".into(),
                    },
                ],
                cloud: CloudConfig { enabled: false, rtt_ms: 80.0, bandwidth_mbps: 50.0 },
            },
            workload: WorkloadConfig {
                prompts: 500,
                seed: 42,
                categories: Vec::new(),
                arrival: Arrival::Closed,
            },
            serving: ServingConfig {
                batch_size: 4,
                batch_timeout_ms: 50.0,
                strategy: "latency-aware".into(),
                execution: ExecutionMode::Calibrated,
                max_new_tokens: 96,
                deferrable_frac: 0.0,
                deferrable_deadline_s: 4.0 * 3600.0,
                defer: true,
                carbon_sizing: false,
                replan: false,
                replan_interval_s: 900.0,
                drift_threshold: 0.2,
                blend: false,
                spot_check_every_n: 0,
                continuous_batching: false,
                failure: crate::simulator::FailurePolicy::default(),
                churn: ChurnConfig::default(),
                http: HttpConfig::default(),
            },
            observability: ObservabilityConfig::default(),
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl ExperimentConfig {
    /// Load from a TOML file; missing sections fall back to defaults.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let value = toml::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        Self::from_value(&value)
    }

    /// Build from a parsed TOML value tree.
    pub fn from_value(v: &Value) -> Result<Self> {
        let mut cfg = Self::default();

        if let Some(c) = v.get("cluster") {
            if let Some(s) = c.get("name").and_then(Value::as_str) {
                cfg.cluster.name = s.to_string();
            }
            if let Some(x) = c.get("carbon_intensity_g_per_kwh").and_then(Value::as_f64) {
                cfg.cluster.carbon_intensity_g_per_kwh = x;
            }
            cfg.cluster.carbon =
                CarbonModelConfig::Constant { g_per_kwh: cfg.cluster.carbon_intensity_g_per_kwh };
            if let Some(cm) = c.get("carbon") {
                cfg.cluster.carbon =
                    parse_carbon_model(cm, cfg.cluster.carbon_intensity_g_per_kwh)?;
                // keep the routing-estimate scalar consistent with the model
                cfg.cluster.carbon_intensity_g_per_kwh = cfg.cluster.carbon.mean_g_per_kwh();
            }
        }
        if let Some(devs) = v.get("device").and_then(Value::as_arr) {
            cfg.cluster.devices = devs
                .iter()
                .map(|d| {
                    let name = d
                        .get("name")
                        .and_then(Value::as_str)
                        .ok_or_else(|| anyhow!("[[device]] missing name"))?
                        .to_string();
                    let kind = DeviceKind::parse(
                        d.get("kind").and_then(Value::as_str).unwrap_or("jetson"),
                    )?;
                    let default_mem = match kind {
                        DeviceKind::Jetson => 8.0,
                        DeviceKind::Ada => 16.0,
                        DeviceKind::Cloud => 80.0,
                    };
                    let default_model = match kind {
                        DeviceKind::Jetson => "edge-1b-sim",
                        _ => "edge-12b-sim",
                    };
                    Ok(DeviceConfig {
                        name,
                        kind,
                        gpu_mem_gb: d
                            .get("gpu_mem_gb")
                            .and_then(Value::as_f64)
                            .unwrap_or(default_mem),
                        model: d
                            .get("model")
                            .and_then(Value::as_str)
                            .unwrap_or(default_model)
                            .to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(c) = v.get("cloud") {
            if let Some(b) = c.get("enabled").and_then(Value::as_bool) {
                cfg.cluster.cloud.enabled = b;
            }
            if let Some(x) = c.get("rtt_ms").and_then(Value::as_f64) {
                cfg.cluster.cloud.rtt_ms = x;
            }
            if let Some(x) = c.get("bandwidth_mbps").and_then(Value::as_f64) {
                cfg.cluster.cloud.bandwidth_mbps = x;
            }
        }
        if let Some(w) = v.get("workload") {
            if let Some(n) = w.get("prompts").and_then(Value::as_usize) {
                cfg.workload.prompts = n;
            }
            if let Some(s) = w.get("seed").and_then(Value::as_u64) {
                cfg.workload.seed = s;
            }
            if let Some(cats) = w.get("categories").and_then(Value::as_arr) {
                cfg.workload.categories = cats
                    .iter()
                    .filter_map(|c| c.as_str().map(str::to_string))
                    .collect();
            }
            if let Some(rate) = w.get("arrival_rate").and_then(Value::as_f64) {
                cfg.workload.arrival =
                    if rate > 0.0 { Arrival::Open { rate } } else { Arrival::Closed };
            }
        }
        if let Some(s) = v.get("serving") {
            if let Some(b) = s.get("batch_size").and_then(Value::as_usize) {
                cfg.serving.batch_size = b;
            }
            if let Some(t) = s.get("batch_timeout_ms").and_then(Value::as_f64) {
                cfg.serving.batch_timeout_ms = t;
            }
            if let Some(st) = s.get("strategy").and_then(Value::as_str) {
                cfg.serving.strategy = st.to_string();
            }
            if let Some(e) = s.get("execution").and_then(Value::as_str) {
                cfg.serving.execution = ExecutionMode::parse(e)?;
            }
            if let Some(m) = s.get("max_new_tokens").and_then(Value::as_usize) {
                cfg.serving.max_new_tokens = m;
            }
            if let Some(f) = s.get("deferrable_frac").and_then(Value::as_f64) {
                cfg.serving.deferrable_frac = f;
            }
            if let Some(d) = s.get("deferrable_deadline_s").and_then(Value::as_f64) {
                cfg.serving.deferrable_deadline_s = d;
            }
            if let Some(b) = s.get("defer").and_then(Value::as_bool) {
                cfg.serving.defer = b;
            }
            if let Some(b) = s.get("carbon_sizing").and_then(Value::as_bool) {
                cfg.serving.carbon_sizing = b;
            }
            if let Some(b) = s.get("replan").and_then(Value::as_bool) {
                cfg.serving.replan = b;
            }
            if let Some(x) = s.get("replan_interval_s").and_then(Value::as_f64) {
                cfg.serving.replan_interval_s = x;
            }
            if let Some(x) = s.get("drift_threshold").and_then(Value::as_f64) {
                cfg.serving.drift_threshold = x;
            }
            if let Some(b) = s.get("blend").and_then(Value::as_bool) {
                cfg.serving.blend = b;
            }
            if let Some(n) = s.get("spot_check_every_n").and_then(Value::as_usize) {
                cfg.serving.spot_check_every_n = n;
            }
            if let Some(b) = s.get("continuous_batching").and_then(Value::as_bool) {
                cfg.serving.continuous_batching = b;
            }
            if let Some(f) = s.get("failure") {
                if let Some(n) = f.get("max_attempts").and_then(Value::as_usize) {
                    cfg.serving.failure.max_attempts = n;
                }
                if let Some(x) = f.get("max_fail_prob").and_then(Value::as_f64) {
                    cfg.serving.failure.max_fail_prob = x;
                }
            }
            if let Some(h) = s.get("http") {
                if let Some(a) = h.get("addr").and_then(Value::as_str) {
                    cfg.serving.http.addr = a.to_string();
                }
                if let Some(n) = h.get("max_queue_depth").and_then(Value::as_usize) {
                    cfg.serving.http.max_queue_depth = n;
                }
                if let Some(x) = h.get("request_timeout_s").and_then(Value::as_f64) {
                    cfg.serving.http.request_timeout_s = x;
                }
                if let Some(n) = h.get("conn_workers").and_then(Value::as_usize) {
                    cfg.serving.http.conn_workers = n;
                }
                if let Some(x) = h.get("idle_timeout_s").and_then(Value::as_f64) {
                    cfg.serving.http.idle_timeout_s = x;
                }
            }
            if let Some(c) = s.get("churn") {
                if let Some(list) = c.get("outages").and_then(Value::as_arr) {
                    cfg.serving.churn.outages = list
                        .iter()
                        .map(|o| {
                            o.as_str().map(str::to_string).ok_or_else(|| {
                                anyhow!(
                                    "[serving.churn] outages must be \
                                     \"device:start_s:end_s\" strings, got {o:?}"
                                )
                            })
                        })
                        .collect::<Result<_>>()?;
                }
                if let Some(x) = c.get("mtbf_s").and_then(Value::as_f64) {
                    cfg.serving.churn.mtbf_s = Some(x);
                }
                if let Some(x) = c.get("mttr_s").and_then(Value::as_f64) {
                    cfg.serving.churn.mttr_s = Some(x);
                }
                if let Some(x) = c.get("horizon_s").and_then(Value::as_f64) {
                    cfg.serving.churn.horizon_s = x;
                }
                if let Some(x) = c.get("seed").and_then(Value::as_u64) {
                    cfg.serving.churn.seed = x;
                }
                if let Some(x) = c.get("degraded_lead_s").and_then(Value::as_f64) {
                    cfg.serving.churn.degraded_lead_s = x;
                }
                if let Some(x) = c.get("recovering_tail_s").and_then(Value::as_f64) {
                    cfg.serving.churn.recovering_tail_s = x;
                }
            }
        }
        if let Some(o) = v.get("observability") {
            if let Some(p) = o.get("trace").and_then(Value::as_str) {
                cfg.observability.trace = Some(p.to_string());
            }
            if let Some(p) = o.get("metrics_json").and_then(Value::as_str) {
                cfg.observability.metrics_json = Some(p.to_string());
            }
        }
        if let Some(a) = v.get("artifacts_dir").and_then(Value::as_str) {
            cfg.artifacts_dir = a.to_string();
        }

        cfg.validate()?;
        Ok(cfg)
    }

    /// Reject configurations that would produce meaningless experiments.
    pub fn validate(&self) -> Result<()> {
        validate_carbon_model(&self.cluster.carbon)?;
        if self.cluster.devices.is_empty() {
            bail!("cluster has no devices");
        }
        let mut names = std::collections::HashSet::new();
        for d in &self.cluster.devices {
            if !names.insert(&d.name) {
                bail!("duplicate device name '{}'", d.name);
            }
            if d.gpu_mem_gb <= 0.0 {
                bail!("device '{}' has non-positive memory", d.name);
            }
        }
        if self.cluster.carbon_intensity_g_per_kwh <= 0.0 {
            bail!("carbon intensity must be positive");
        }
        if self.workload.prompts == 0 {
            bail!("workload.prompts must be >= 1");
        }
        if self.serving.batch_size == 0 || self.serving.batch_size > 64 {
            bail!("serving.batch_size must be in 1..=64");
        }
        if self.serving.max_new_tokens == 0 {
            bail!("serving.max_new_tokens must be >= 1");
        }
        if !(0.0..=1.0).contains(&self.serving.deferrable_frac) {
            bail!(
                "serving.deferrable_frac must be in [0,1], got {}",
                self.serving.deferrable_frac
            );
        }
        if self.serving.deferrable_deadline_s <= 0.0 {
            bail!("serving.deferrable_deadline_s must be positive");
        }
        if !(self.serving.replan_interval_s > 0.0 && self.serving.replan_interval_s.is_finite()) {
            bail!(
                "serving.replan_interval_s must be positive and finite, got {}",
                self.serving.replan_interval_s
            );
        }
        if !(self.serving.drift_threshold > 0.0 && self.serving.drift_threshold.is_finite()) {
            bail!(
                "serving.drift_threshold must be positive and finite, got {}",
                self.serving.drift_threshold
            );
        }
        if let Arrival::Open { rate } = self.workload.arrival {
            if rate <= 0.0 {
                bail!("open arrival rate must be positive");
            }
        }
        if self.serving.http.addr.is_empty() {
            bail!("[serving.http] addr must not be empty");
        }
        if !(self.serving.http.request_timeout_s > 0.0
            && self.serving.http.request_timeout_s.is_finite())
        {
            bail!(
                "[serving.http] request_timeout_s must be positive and finite, got {}",
                self.serving.http.request_timeout_s
            );
        }
        if !(self.serving.http.idle_timeout_s > 0.0 && self.serving.http.idle_timeout_s.is_finite())
        {
            bail!(
                "[serving.http] idle_timeout_s must be positive and finite, got {}",
                self.serving.http.idle_timeout_s
            );
        }
        self.serving.failure.validate()?;
        self.serving.churn.validate()?;
        Ok(())
    }

    /// Find a device by name.
    pub fn device(&self, name: &str) -> Option<&DeviceConfig> {
        self.cluster.devices.iter().find(|d| d.name == name)
    }
}

/// Parse the `[cluster.carbon]` table; `default_mean` is the cluster's
/// scalar intensity (used when the table omits a mean).
fn parse_carbon_model(cm: &Value, default_mean: f64) -> Result<CarbonModelConfig> {
    let model = cm.get("model").and_then(Value::as_str).unwrap_or("constant");
    let mean = cm
        .get("mean_g_per_kwh")
        .and_then(Value::as_f64)
        .unwrap_or(default_mean);
    let swing = cm.get("swing").and_then(Value::as_f64).unwrap_or(0.3);
    let step_s = cm.get("step_s").and_then(Value::as_f64).unwrap_or(900.0);
    match model {
        "constant" => Ok(CarbonModelConfig::Constant { g_per_kwh: mean }),
        "diurnal" => Ok(CarbonModelConfig::Diurnal { mean_g_per_kwh: mean, swing }),
        "trace" => {
            // real-world CSV ingestion: `trace_file` points at an
            // ElectricityMaps/WattTime-style timestamp,gCO2/kWh file
            if let Some(path) = cm.get("trace_file").and_then(Value::as_str) {
                let trace = crate::grid::GridTrace::from_csv(Path::new(path))
                    .map_err(|e| e.context(format!("[cluster.carbon] trace_file = \"{path}\"")))?;
                return Ok(CarbonModelConfig::Trace {
                    step_s: trace.step_s,
                    samples: trace.samples().to_vec(),
                });
            }
            let samples: Vec<f64> = cm
                .get("samples")
                .and_then(Value::as_arr)
                .ok_or_else(|| {
                    anyhow!("[cluster.carbon] model=trace needs samples = [..] or trace_file = \"...\"")
                })?
                .iter()
                .map(|s| {
                    s.as_f64().ok_or_else(|| {
                        anyhow!("[cluster.carbon] samples must all be numbers, got {s:?}")
                    })
                })
                .collect::<Result<_>>()?;
            Ok(CarbonModelConfig::Trace { step_s, samples })
        }
        "synthetic" => Ok(CarbonModelConfig::Synthetic {
            mean_g_per_kwh: mean,
            swing,
            weekly_swing: cm.get("weekly_swing").and_then(Value::as_f64).unwrap_or(0.0),
            noise: cm.get("noise").and_then(Value::as_f64).unwrap_or(0.0),
            days: cm.get("days").and_then(Value::as_usize).unwrap_or(2),
            step_s,
            seed: cm.get("seed").and_then(Value::as_u64).unwrap_or(42),
        }),
        other => bail!("unknown carbon model '{other}' (constant|diurnal|trace|synthetic)"),
    }
}

fn validate_carbon_model(cm: &CarbonModelConfig) -> Result<()> {
    let positive = |x: f64, what: &str| -> Result<()> {
        if x > 0.0 && x.is_finite() {
            Ok(())
        } else {
            bail!("carbon model: {what} must be positive, got {x}")
        }
    };
    match cm {
        CarbonModelConfig::Constant { g_per_kwh } => positive(*g_per_kwh, "intensity"),
        CarbonModelConfig::Diurnal { mean_g_per_kwh, swing } => {
            positive(*mean_g_per_kwh, "mean intensity")?;
            if !(0.0..1.0).contains(swing) {
                bail!("carbon model: swing must be in [0,1), got {swing}");
            }
            Ok(())
        }
        CarbonModelConfig::Trace { step_s, samples } => {
            positive(*step_s, "step_s")?;
            if samples.is_empty() {
                bail!("carbon model: trace needs at least one sample");
            }
            for s in samples {
                positive(*s, "trace sample")?;
            }
            Ok(())
        }
        CarbonModelConfig::Synthetic {
            mean_g_per_kwh,
            swing,
            weekly_swing,
            noise,
            days,
            step_s,
            ..
        } => {
            positive(*mean_g_per_kwh, "mean intensity")?;
            positive(*step_s, "step_s")?;
            for (x, what) in
                [(swing, "swing"), (weekly_swing, "weekly_swing"), (noise, "noise")]
            {
                if !(0.0..1.0).contains(x) {
                    bail!("carbon model: {what} must be in [0,1), got {x}");
                }
            }
            if *days == 0 {
                bail!("carbon model: synthetic trace needs days >= 1");
            }
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid_and_matches_paper() {
        let c = ExperimentConfig::default();
        c.validate().unwrap();
        assert_eq!(c.cluster.devices.len(), 2);
        assert_eq!(c.workload.prompts, 500);
        assert!((c.cluster.carbon_intensity_g_per_kwh - 69.0).abs() < 1e-9);
    }

    #[test]
    fn load_full_toml() {
        let doc = r#"
[cluster]
name = "lab"
carbon_intensity_g_per_kwh = 100.0

[[device]]
name = "j1"
kind = "jetson"
gpu_mem_gb = 8.0
model = "edge-1b-sim"

[[device]]
name = "a1"
kind = "ada"

[cloud]
enabled = true
rtt_ms = 120.0

[workload]
prompts = 64
seed = 7
arrival_rate = 2.5

[serving]
batch_size = 8
strategy = "carbon-aware"
execution = "hybrid"
max_new_tokens = 32
"#;
        let v = toml::parse(doc).unwrap();
        let c = ExperimentConfig::from_value(&v).unwrap();
        assert_eq!(c.cluster.name, "lab");
        assert_eq!(c.cluster.devices[1].name, "a1");
        assert_eq!(c.cluster.devices[1].gpu_mem_gb, 16.0); // kind default
        assert_eq!(c.cluster.devices[1].model, "edge-12b-sim");
        assert!(c.cluster.cloud.enabled);
        assert_eq!(c.workload.prompts, 64);
        assert_eq!(c.workload.arrival, Arrival::Open { rate: 2.5 });
        assert_eq!(c.serving.batch_size, 8);
        assert_eq!(c.serving.execution, ExecutionMode::Hybrid);
    }

    #[test]
    fn rejects_bad_configs() {
        let mut c = ExperimentConfig::default();
        c.serving.batch_size = 0;
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::default();
        c.workload.prompts = 0;
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::default();
        c.cluster.devices[1].name = c.cluster.devices[0].name.clone();
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::default();
        c.cluster.carbon_intensity_g_per_kwh = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn carbon_model_toml_roundtrip() {
        // diurnal
        let doc = r#"
[cluster]
carbon_intensity_g_per_kwh = 50.0

[cluster.carbon]
model = "diurnal"
mean_g_per_kwh = 80.0
swing = 0.25
"#;
        let c = ExperimentConfig::from_value(&toml::parse(doc).unwrap()).unwrap();
        assert_eq!(
            c.cluster.carbon,
            CarbonModelConfig::Diurnal { mean_g_per_kwh: 80.0, swing: 0.25 }
        );
        // the routing scalar follows the model's mean
        assert_eq!(c.cluster.carbon_intensity_g_per_kwh, 80.0);

        // explicit trace with inline samples
        let doc = r#"
[cluster.carbon]
model = "trace"
step_s = 1800.0
samples = [40.0, 90.0, 60.0]
"#;
        let c = ExperimentConfig::from_value(&toml::parse(doc).unwrap()).unwrap();
        let CarbonModelConfig::Trace { step_s, ref samples } = c.cluster.carbon else {
            panic!("expected trace model, got {:?}", c.cluster.carbon)
        };
        assert_eq!(step_s, 1800.0);
        assert_eq!(samples, &vec![40.0, 90.0, 60.0]);
        let mean = (40.0 + 90.0 + 60.0) / 3.0;
        assert!((c.cluster.carbon_intensity_g_per_kwh - mean).abs() < 1e-12);

        // synthetic with defaults filled in
        let doc = r#"
[cluster.carbon]
model = "synthetic"
noise = 0.05
days = 3
seed = 7
"#;
        let c = ExperimentConfig::from_value(&toml::parse(doc).unwrap()).unwrap();
        assert_eq!(
            c.cluster.carbon,
            CarbonModelConfig::Synthetic {
                mean_g_per_kwh: 69.0,
                swing: 0.3,
                weekly_swing: 0.0,
                noise: 0.05,
                days: 3,
                step_s: 900.0,
                seed: 7,
            }
        );

        // no [cluster.carbon] table: constant at the scalar (back-compat)
        let doc = "[cluster]\ncarbon_intensity_g_per_kwh = 120.0\n";
        let c = ExperimentConfig::from_value(&toml::parse(doc).unwrap()).unwrap();
        assert_eq!(c.cluster.carbon, CarbonModelConfig::Constant { g_per_kwh: 120.0 });
    }

    #[test]
    fn carbon_model_rejects_bad_configs() {
        let parse = |doc: &str| ExperimentConfig::from_value(&toml::parse(doc).unwrap());
        assert!(parse("[cluster.carbon]\nmodel = \"volcanic\"\n").is_err());
        assert!(parse("[cluster.carbon]\nmodel = \"trace\"\n").is_err()); // no samples
        assert!(parse("[cluster.carbon]\nmodel = \"trace\"\nsamples = [10.0, -1.0]\n").is_err());
        // non-numeric samples are rejected, not silently dropped
        assert!(
            parse("[cluster.carbon]\nmodel = \"trace\"\nsamples = [10.0, \"oops\"]\n").is_err()
        );
        assert!(parse("[cluster.carbon]\nmodel = \"diurnal\"\nswing = 1.5\n").is_err());
        assert!(parse("[cluster.carbon]\nmodel = \"synthetic\"\ndays = 0\n").is_err());

        let mut c = ExperimentConfig::default();
        c.cluster.carbon = CarbonModelConfig::Constant { g_per_kwh: -3.0 };
        assert!(c.validate().is_err());
    }

    #[test]
    fn serving_slo_and_sizing_knobs() {
        // defaults preserve the paper's behaviour exactly
        let d = ExperimentConfig::default();
        assert_eq!(d.serving.deferrable_frac, 0.0);
        assert!(d.serving.defer);
        assert!(!d.serving.carbon_sizing);

        let doc = r#"
[serving]
deferrable_frac = 0.4
deferrable_deadline_s = 7200.0
defer = false
carbon_sizing = true
"#;
        let c = ExperimentConfig::from_value(&toml::parse(doc).unwrap()).unwrap();
        assert_eq!(c.serving.deferrable_frac, 0.4);
        assert_eq!(c.serving.deferrable_deadline_s, 7200.0);
        assert!(!c.serving.defer);
        assert!(c.serving.carbon_sizing);

        let parse = |doc: &str| ExperimentConfig::from_value(&toml::parse(doc).unwrap());
        assert!(parse("[serving]\ndeferrable_frac = 1.5\n").is_err());
        assert!(parse("[serving]\ndeferrable_deadline_s = 0.0\n").is_err());
    }

    #[test]
    fn replan_knobs_roundtrip_and_validate() {
        // defaults: replan off (plan-once), blend off, sane
        // cadence/threshold
        let d = ExperimentConfig::default();
        assert!(!d.serving.replan);
        assert_eq!(d.serving.replan_interval_s, 900.0);
        assert_eq!(d.serving.drift_threshold, 0.2);
        assert!(!d.serving.blend);

        let doc = r#"
[serving]
replan = true
replan_interval_s = 1800.0
drift_threshold = 0.35
blend = true
"#;
        let c = ExperimentConfig::from_value(&toml::parse(doc).unwrap()).unwrap();
        assert!(c.serving.replan);
        assert_eq!(c.serving.replan_interval_s, 1800.0);
        assert_eq!(c.serving.drift_threshold, 0.35);
        assert!(c.serving.blend);

        let parse = |doc: &str| ExperimentConfig::from_value(&toml::parse(doc).unwrap());
        assert!(parse("[serving]\nreplan_interval_s = 0.0\n").is_err());
        assert!(parse("[serving]\nreplan_interval_s = -5.0\n").is_err());
        assert!(parse("[serving]\ndrift_threshold = 0.0\n").is_err());
        assert!(parse("[serving]\ndrift_threshold = -0.1\n").is_err());
    }

    #[test]
    fn carbon_trace_file_roundtrip_and_error_paths() {
        let dir = std::env::temp_dir();
        let good = dir.join("verdant_cfg_trace.csv");
        std::fs::write(&good, "timestamp,gCO2/kWh\n0,40.0\n1800,90.0\n3600,60.0\n").unwrap();
        let doc = format!(
            "[cluster.carbon]\nmodel = \"trace\"\ntrace_file = \"{}\"\n",
            good.display()
        );
        let c = ExperimentConfig::from_value(&toml::parse(&doc).unwrap()).unwrap();
        let CarbonModelConfig::Trace { step_s, ref samples } = c.cluster.carbon else {
            panic!("expected trace model, got {:?}", c.cluster.carbon)
        };
        assert_eq!(step_s, 1800.0);
        assert_eq!(samples, &vec![40.0, 90.0, 60.0]);
        // the routing scalar follows the file's mean
        let mean = (40.0 + 90.0 + 60.0) / 3.0;
        assert!((c.cluster.carbon_intensity_g_per_kwh - mean).abs() < 1e-12);
        std::fs::remove_file(&good).ok();

        // malformed file: the error names the offending path
        let bad = dir.join("verdant_cfg_trace_bad.csv");
        std::fs::write(&bad, "0,40.0\n900,-3.0\n").unwrap();
        let doc = format!(
            "[cluster.carbon]\nmodel = \"trace\"\ntrace_file = \"{}\"\n",
            bad.display()
        );
        let err = ExperimentConfig::from_value(&toml::parse(&doc).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("trace_file"), "{err}");
        std::fs::remove_file(&bad).ok();

        // missing file errors instead of silently falling back
        let doc = "[cluster.carbon]\nmodel = \"trace\"\ntrace_file = \"/nonexistent/x.csv\"\n";
        assert!(ExperimentConfig::from_value(&toml::parse(doc).unwrap()).is_err());
    }

    #[test]
    fn observability_table_roundtrip() {
        // default: tracing and the metrics dump are both off
        let d = ExperimentConfig::default();
        assert!(d.observability.trace.is_none());
        assert!(d.observability.metrics_json.is_none());
        assert_eq!(d.serving.spot_check_every_n, 0);

        let doc = r#"
[serving]
spot_check_every_n = 16

[observability]
trace = "out/decisions.jsonl"
metrics_json = "out/metrics.json"
"#;
        let c = ExperimentConfig::from_value(&toml::parse(doc).unwrap()).unwrap();
        assert_eq!(c.observability.trace.as_deref(), Some("out/decisions.jsonl"));
        assert_eq!(c.observability.metrics_json.as_deref(), Some("out/metrics.json"));
        assert_eq!(c.serving.spot_check_every_n, 16);
    }

    #[test]
    fn failure_and_churn_tables_roundtrip() {
        use crate::simulator::FailurePolicy;
        // defaults: historic retry constants, churn off, no schedule
        let d = ExperimentConfig::default();
        assert_eq!(d.serving.failure, FailurePolicy::default());
        assert!(!d.serving.churn.is_enabled());
        assert!(d.serving.churn.to_schedule(2).unwrap().is_none());

        // scripted outages + custom retry budget
        let doc = r#"
[serving.failure]
max_attempts = 5
max_fail_prob = 0.5

[serving.churn]
outages = ["0:10:20", "1:30:40"]
degraded_lead_s = 5.0
"#;
        let c = ExperimentConfig::from_value(&toml::parse(doc).unwrap()).unwrap();
        assert_eq!(c.serving.failure.max_attempts, 5);
        assert_eq!(c.serving.failure.max_fail_prob, 0.5);
        assert!(c.serving.churn.is_enabled());
        let s = c.serving.churn.to_schedule(2).unwrap().expect("churn on");
        assert_eq!(s.windows().len(), 2);
        assert_eq!(s.max_device(), Some(1));

        // stochastic model is deterministic under a fixed seed
        let doc = r#"
[serving.churn]
mtbf_s = 500.0
mttr_s = 60.0
horizon_s = 1000.0
seed = 9
"#;
        let c = ExperimentConfig::from_value(&toml::parse(doc).unwrap()).unwrap();
        let s1 = c.serving.churn.to_schedule(2).unwrap().expect("churn on");
        let s2 = c.serving.churn.to_schedule(2).unwrap().expect("churn on");
        assert_eq!(s1, s2, "same seed must sample the same outages");

        let parse = |doc: &str| ExperimentConfig::from_value(&toml::parse(doc).unwrap());
        // retry budget of zero is meaningless
        assert!(parse("[serving.failure]\nmax_attempts = 0\n").is_err());
        assert!(parse("[serving.failure]\nmax_fail_prob = 1.5\n").is_err());
        // scripted and stochastic churn cannot mix
        assert!(
            parse("[serving.churn]\noutages = [\"0:1:2\"]\nmtbf_s = 10.0\nmttr_s = 1.0\n").is_err()
        );
        // stochastic needs both halves
        assert!(parse("[serving.churn]\nmtbf_s = 10.0\n").is_err());
        // malformed window specs fail at load time, not run time
        assert!(parse("[serving.churn]\noutages = [\"oops\"]\n").is_err());
        assert!(parse("[serving.churn]\noutages = [\"0:20:10\"]\n").is_err());
        assert!(parse("[serving.churn]\noutages = [\"0:1:2\"]\ndegraded_lead_s = -1.0\n").is_err());
        // a window naming a missing device fails when materialized
        let c = parse("[serving.churn]\noutages = [\"99:0:10\"]\n").unwrap();
        let err = c.serving.churn.to_schedule(2).unwrap_err().to_string();
        assert!(err.contains("names device 99"), "{err}");
    }

    #[test]
    fn http_table_roundtrip() {
        // defaults: loopback, bounded queue, 30 s timeout
        let d = ExperimentConfig::default();
        assert_eq!(d.serving.http, HttpConfig::default());
        assert_eq!(d.serving.http.addr, "127.0.0.1:8080");
        assert_eq!(d.serving.http.max_queue_depth, 256);

        let doc = r#"
[serving.http]
addr = "0.0.0.0:9001"
max_queue_depth = 8
request_timeout_s = 2.5
conn_workers = 4
idle_timeout_s = 0.25
"#;
        let c = ExperimentConfig::from_value(&toml::parse(doc).unwrap()).unwrap();
        assert_eq!(c.serving.http.addr, "0.0.0.0:9001");
        assert_eq!(c.serving.http.max_queue_depth, 8);
        assert_eq!(c.serving.http.request_timeout_s, 2.5);
        assert_eq!(c.serving.http.conn_workers, 4);
        assert_eq!(c.serving.http.idle_timeout_s, 0.25);

        let parse = |doc: &str| ExperimentConfig::from_value(&toml::parse(doc).unwrap());
        assert!(parse("[serving.http]\naddr = \"\"\n").is_err());
        assert!(parse("[serving.http]\nrequest_timeout_s = 0.0\n").is_err());
        assert!(parse("[serving.http]\nrequest_timeout_s = -1.0\n").is_err());
        assert!(parse("[serving.http]\nidle_timeout_s = 0.0\n").is_err());
        assert!(parse("[serving.http]\nidle_timeout_s = -2.0\n").is_err());
    }

    #[test]
    fn execution_mode_roundtrip() {
        for m in [
            ExecutionMode::Real,
            ExecutionMode::Calibrated,
            ExecutionMode::Hybrid,
            ExecutionMode::Stub,
        ] {
            assert_eq!(ExecutionMode::parse(m.name()).unwrap(), m);
        }
        assert!(ExecutionMode::parse("gpu").is_err());
    }

    #[test]
    fn device_lookup() {
        let c = ExperimentConfig::default();
        assert!(c.device("jetson-orin-nx").is_some());
        assert!(c.device("nope").is_none());
    }
}
