//! The eight prompt categories of the paper's composite benchmark.
//!
//! Each category carries the distribution parameters the synthetic
//! generator needs: corpus mix weight, log-normal prompt/output token
//! distributions, and a base complexity level. Values are chosen to
//! match the qualitative description in §3 of the paper (e.g. python
//! coding = low prompt / high output "compute-intensive" tasks; SQuAD =
//! long context / short extract; arXiv = long-form summarization).

/// Prompt category (source dataset in the paper's composite benchmark).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Category {
    /// GSM8K math word problems — multi-step reasoning.
    Gsm8k,
    /// SQuAD extractive question answering — long context, short answer.
    Squad,
    /// DialogSum dialogue summarization.
    DialogSum,
    /// python_code_instructions_18k — code generation.
    PythonCode,
    /// ARC-Challenge multiple-choice science reasoning.
    ArcChallenge,
    /// Long-form summarization of arXiv papers.
    ArxivSumm,
    /// DailyDialog multi-turn dialogue continuation.
    DailyDialog,
    /// CNN/DailyMail general long-form summarization.
    CnnDm,
}

/// Distribution parameters for one category.
#[derive(Debug, Clone, Copy)]
pub struct CategoryProfile {
    /// Mix weight in the composite corpus.
    pub weight: f64,
    /// Median prompt length, tokens (log-normal).
    pub prompt_median: f64,
    /// Log-normal sigma for prompt length.
    pub prompt_sigma: f64,
    /// Median output demand, tokens (log-normal, model-independent).
    pub output_median: f64,
    /// Log-normal sigma for output demand.
    pub output_sigma: f64,
    /// Base complexity contribution (judge substitute feature).
    pub base_complexity: f64,
}

impl Category {
    pub const ALL: [Category; 8] = [
        Category::Gsm8k,
        Category::Squad,
        Category::DialogSum,
        Category::PythonCode,
        Category::ArcChallenge,
        Category::ArxivSumm,
        Category::DailyDialog,
        Category::CnnDm,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Category::Gsm8k => "gsm8k",
            Category::Squad => "squad",
            Category::DialogSum => "dialogsum",
            Category::PythonCode => "python-code",
            Category::ArcChallenge => "arc-challenge",
            Category::ArxivSumm => "arxiv-summ",
            Category::DailyDialog => "dailydialog",
            Category::CnnDm => "cnn-dm",
        }
    }

    pub fn parse(s: &str) -> Option<Category> {
        Category::ALL.iter().copied().find(|c| c.name() == s)
    }

    pub fn profile(&self) -> CategoryProfile {
        match self {
            Category::Gsm8k => CategoryProfile {
                weight: 0.15,
                prompt_median: 90.0,
                prompt_sigma: 0.30,
                output_median: 110.0,
                output_sigma: 0.30,
                base_complexity: 0.55,
            },
            Category::Squad => CategoryProfile {
                weight: 0.15,
                prompt_median: 160.0,
                prompt_sigma: 0.35,
                output_median: 18.0,
                output_sigma: 0.40,
                base_complexity: 0.15,
            },
            Category::DialogSum => CategoryProfile {
                weight: 0.12,
                prompt_median: 220.0,
                prompt_sigma: 0.40,
                output_median: 70.0,
                output_sigma: 0.30,
                base_complexity: 0.35,
            },
            Category::PythonCode => CategoryProfile {
                weight: 0.13,
                prompt_median: 60.0,
                prompt_sigma: 0.40,
                output_median: 190.0,
                output_sigma: 0.35,
                base_complexity: 0.60,
            },
            Category::ArcChallenge => CategoryProfile {
                weight: 0.12,
                prompt_median: 80.0,
                prompt_sigma: 0.30,
                output_median: 12.0,
                output_sigma: 0.40,
                base_complexity: 0.30,
            },
            Category::ArxivSumm => CategoryProfile {
                weight: 0.10,
                prompt_median: 380.0,
                prompt_sigma: 0.35,
                output_median: 160.0,
                output_sigma: 0.30,
                base_complexity: 0.50,
            },
            Category::DailyDialog => CategoryProfile {
                weight: 0.13,
                prompt_median: 110.0,
                prompt_sigma: 0.40,
                output_median: 45.0,
                output_sigma: 0.40,
                base_complexity: 0.25,
            },
            Category::CnnDm => CategoryProfile {
                weight: 0.10,
                prompt_median: 300.0,
                prompt_sigma: 0.35,
                output_median: 90.0,
                output_sigma: 0.30,
                base_complexity: 0.40,
            },
        }
    }

    /// Seed phrase used by the synthetic text generator.
    pub fn seed_phrase(&self) -> &'static str {
        match self {
            Category::Gsm8k => {
                "Solve the following math word problem step by step and show your reasoning:"
            }
            Category::Squad => {
                "Answer the question using only the passage below. Passage:"
            }
            Category::DialogSum => {
                "Summarize the following dialogue in two sentences. Dialogue:"
            }
            Category::PythonCode => {
                "Write a Python function with docstring and tests that"
            }
            Category::ArcChallenge => {
                "Choose the correct answer (A, B, C or D) for this science question:"
            }
            Category::ArxivSumm => {
                "Provide a detailed summary of the key contributions of this paper. Abstract:"
            }
            Category::DailyDialog => {
                "Continue this conversation naturally. Conversation so far:"
            }
            Category::CnnDm => {
                "Summarize this news article, highlighting the main events. Article:"
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weights_sum_to_one() {
        let total: f64 = Category::ALL.iter().map(|c| c.profile().weight).sum();
        assert!((total - 1.0).abs() < 1e-9, "total={total}");
    }

    #[test]
    fn names_roundtrip() {
        for c in Category::ALL {
            assert_eq!(Category::parse(c.name()), Some(c));
        }
        assert_eq!(Category::parse("nope"), None);
    }

    #[test]
    fn profiles_are_sane() {
        for c in Category::ALL {
            let p = c.profile();
            assert!(p.weight > 0.0 && p.weight < 1.0);
            assert!(p.prompt_median >= 10.0);
            assert!(p.output_median >= 5.0);
            assert!((0.0..=1.0).contains(&p.base_complexity));
            assert!(p.prompt_sigma > 0.0 && p.output_sigma > 0.0);
        }
    }

    #[test]
    fn paper_asymmetries_present() {
        // python coding: low prompt, high output ("compute-intensive")
        let py = Category::PythonCode.profile();
        assert!(py.output_median > 2.0 * py.prompt_median);
        // squad: long context, short extraction
        let sq = Category::Squad.profile();
        assert!(sq.prompt_median > 5.0 * sq.output_median);
        // arxiv: heavy on both ends (memory-intensive long-form)
        let ax = Category::ArxivSumm.profile();
        assert!(ax.prompt_median > 300.0 && ax.output_median > 100.0);
    }
}
