//! The paper's Table 1 prompts (P1–P4), verbatim, with their published
//! complexity scores. These drive the Fig. 1 / Fig. 2 motivation
//! experiments and calibrate the complexity judge substitute.

use super::{complexity, Category, Prompt, SloClass};

/// One canonical prompt with the paper's metadata.
#[derive(Debug, Clone)]
pub struct CanonicalPrompt {
    pub id: &'static str,
    pub text: &'static str,
    /// CS published in Table 1.
    pub paper_cs: f64,
    /// Expected output demand (tokens) implied by the task.
    pub output_demand_tokens: usize,
    /// Closest composite-benchmark category.
    pub category: Category,
}

/// P1 — constraint-satisfaction reasoning (Table 1, CS 0.47).
pub const P1: CanonicalPrompt = CanonicalPrompt {
    id: "P1",
    text: "A group of five friends (Alice, Bob, Carol, David, Emily) are trying \
to decide who will buy tickets for a concert, prepare snacks, drive, and pick \
up drinks. Alice hates driving. Bob can only pick up drinks if he's not \
preparing snacks. Carol loves concerts and wants to buy tickets. David can \
only drive if Emily prepares snacks. Emily will not pick up drinks. Each \
friend must take exactly one task, and each task must be assigned to exactly \
one friend. Assign the tasks to each friend and explain your logical \
deduction step by step.",
    paper_cs: 0.47,
    output_demand_tokens: 260,
    category: Category::Gsm8k,
};

/// P2 — generative writing (Table 1, CS 0.39).
pub const P2: CanonicalPrompt = CanonicalPrompt {
    id: "P2",
    text: "Write a short story, approximately 500 words, about a sentient, \
self-repairing antique grandfather clock that secretly orchestrates minor, \
benevolent 'time anomalies' in a quiet, forgotten library. Introduce a \
skeptical new librarian who slowly uncovers the clock's secret. The story \
must include: The clock's motivation for its actions. Three distinct 'time \
anomalies' are caused. A moment of direct, non-verbal communication between \
the clock and the librarian. A surprising twist where the librarian, instead \
of exposing the clock, aids its efforts for an unexpected reason.",
    paper_cs: 0.39,
    output_demand_tokens: 520,
    category: Category::CnnDm,
};

/// P3 — factual lookup (Table 1, CS 0.08).
pub const P3: CanonicalPrompt = CanonicalPrompt {
    id: "P3",
    text: "What is the boiling point of water at standard atmospheric pressure?",
    paper_cs: 0.08,
    output_demand_tokens: 14,
    category: Category::Squad,
};

/// P4 — factual lookup (Table 1, CS 0.07).
pub const P4: CanonicalPrompt = CanonicalPrompt {
    id: "P4",
    text: "Who painted the Mona Lisa?",
    paper_cs: 0.07,
    output_demand_tokens: 10,
    category: Category::ArcChallenge,
};

/// All four canonical prompts in paper order.
pub const ALL: [&CanonicalPrompt; 4] = [&P1, &P2, &P3, &P4];

impl CanonicalPrompt {
    /// Our judge substitute's CS for this prompt.
    pub fn scored_cs(&self) -> f64 {
        complexity::score(self.text, self.output_demand_tokens)
    }

    /// Convert into a workload [`Prompt`] (arrival t=0, given id).
    pub fn to_prompt(&self, id: u64) -> Prompt {
        Prompt {
            id,
            category: self.category,
            text: self.text.to_string(),
            prompt_tokens: super::tokenizer::count(self.text),
            output_demand_tokens: self.output_demand_tokens,
            complexity: self.scored_cs(),
            arrival_s: 0.0,
            slo: SloClass::Interactive,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn judge_reproduces_paper_scores() {
        // the scorer was calibrated against these; tolerance ±0.06 abs
        for p in ALL {
            let cs = p.scored_cs();
            assert!(
                (cs - p.paper_cs).abs() < 0.06,
                "{}: scored {cs:.3} vs paper {}",
                p.id,
                p.paper_cs
            );
        }
    }

    #[test]
    fn ordering_matches_paper() {
        // P1 > P2 >> P3 > P4
        let cs: Vec<f64> = ALL.iter().map(|p| p.scored_cs()).collect();
        assert!(cs[0] > cs[1], "P1 {} vs P2 {}", cs[0], cs[1]);
        assert!(cs[1] > cs[2] + 0.2);
        assert!(cs[2] > cs[3]);
    }

    #[test]
    fn to_prompt_is_consistent() {
        let p = P1.to_prompt(7);
        assert_eq!(p.id, 7);
        assert_eq!(p.prompt_tokens, P1.text.len());
        assert!(p.complexity > 0.4);
    }
}
