//! Byte-level tokenizer shared with the AOT models (vocab = 256).
//!
//! The L2 artifacts are lowered with a 256-entry vocabulary, so the
//! tokenizer is a byte mapping: token id = byte value, with id 0
//! reserved as EOS/pad (NUL never appears in prompt text). This keeps
//! the Rust request path and the Python compile path trivially in sync
//! (python/compile/configs.py: VOCAB = 256, EOS_ID = 0).

/// Vocabulary size baked into the artifacts.
pub const VOCAB: usize = 256;
/// EOS / padding token id.
pub const EOS_ID: i32 = 0;

/// Encode text to token ids (bytes). NUL bytes are mapped to 1 so the
/// EOS id can never appear inside a prompt.
pub fn encode(text: &str) -> Vec<i32> {
    text.bytes().map(|b| if b == 0 { 1 } else { b as i32 }).collect()
}

/// Decode ids back to text; EOS terminates, invalid UTF-8 is replaced.
pub fn decode(ids: &[i32]) -> String {
    let bytes: Vec<u8> = ids
        .iter()
        .take_while(|&&id| id != EOS_ID)
        .map(|&id| (id.clamp(0, 255)) as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Number of tokens in a text (byte count).
pub fn count(text: &str) -> usize {
    text.len()
}

/// Truncate-or-right-pad to exactly `len` ids, returning (ids, true_len).
/// The true length is always >= 1 (empty prompts become a single pad-1
/// token) because prefill gathers logits at index len-1.
pub fn to_fixed(text: &str, len: usize) -> (Vec<i32>, usize) {
    let mut ids = encode(text);
    ids.truncate(len);
    if ids.is_empty() {
        ids.push(1);
    }
    let true_len = ids.len();
    ids.resize(len, EOS_ID);
    (ids, true_len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let text = "Who painted the Mona Lisa?";
        assert_eq!(decode(&encode(text)), text);
        assert_eq!(count(text), text.len());
    }

    #[test]
    fn eos_terminates_decode() {
        let ids = vec![72, 105, EOS_ID, 33];
        assert_eq!(decode(&ids), "Hi");
    }

    #[test]
    fn nul_bytes_remapped() {
        let ids = encode("a\0b");
        assert!(!ids.contains(&EOS_ID));
    }

    #[test]
    fn to_fixed_pads_and_truncates() {
        let (ids, len) = to_fixed("abc", 6);
        assert_eq!(ids, vec![97, 98, 99, 0, 0, 0]);
        assert_eq!(len, 3);

        let (ids, len) = to_fixed("abcdefgh", 4);
        assert_eq!(ids.len(), 4);
        assert_eq!(len, 4);
        assert_eq!(ids, vec![97, 98, 99, 100]);
    }

    #[test]
    fn empty_prompt_gets_sentinel() {
        let (ids, len) = to_fixed("", 4);
        assert_eq!(len, 1);
        assert_eq!(ids[0], 1);
    }

    #[test]
    fn ids_in_vocab_range() {
        let ids = encode("héllo 😀");
        assert!(ids.iter().all(|&i| i > 0 && i < VOCAB as i32));
    }
}
