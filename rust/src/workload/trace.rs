//! Arrival traces: closed-loop (the paper's setup) and open-loop Poisson.
//!
//! The paper queues all 500 prompts at t=0 and measures makespan
//! (closed). The serving extension experiments replay the same corpus as
//! a Poisson stream to study batching timeouts and queueing delay under
//! load (open).

use crate::config::Arrival;
use crate::util::rng::Rng;

use super::{Prompt, SloClass};

/// Assign arrival times to a corpus in place according to the process.
pub fn assign_arrivals(prompts: &mut [Prompt], arrival: Arrival, seed: u64) {
    match arrival {
        Arrival::Closed => {
            for p in prompts.iter_mut() {
                p.arrival_s = 0.0;
            }
        }
        Arrival::Open { rate } => {
            let mut rng = Rng::new(seed ^ 0xA881_77E5);
            let mut t = 0.0;
            for p in prompts.iter_mut() {
                t += rng.exponential(rate);
                p.arrival_s = t;
            }
        }
    }
}

/// Total span of the trace (last arrival), seconds.
pub fn span(prompts: &[Prompt]) -> f64 {
    prompts.iter().map(|p| p.arrival_s).fold(0.0, f64::max)
}

/// Mark a seeded `deferrable_frac` of the corpus as
/// [`SloClass::Deferrable`] with the given completion deadline; the
/// rest stay `Interactive`. Deterministic per seed, independent of the
/// arrival process so the same corpus can be replayed across
/// deferrable fractions.
pub fn assign_slos(prompts: &mut [Prompt], deferrable_frac: f64, deadline_s: f64, seed: u64) {
    assert!((0.0..=1.0).contains(&deferrable_frac), "fraction in [0,1]");
    assert!(deadline_s > 0.0, "deadline must be positive");
    let mut rng = Rng::new(seed ^ 0x510_C1A55);
    for p in prompts.iter_mut() {
        p.slo = if rng.chance(deferrable_frac) {
            SloClass::Deferrable { deadline_s }
        } else {
            SloClass::Interactive
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::workload::Corpus;

    fn corpus(n: usize) -> Vec<Prompt> {
        Corpus::generate(&WorkloadConfig {
            prompts: n,
            seed: 5,
            categories: Vec::new(),
            arrival: Arrival::Closed,
        })
        .prompts
    }

    #[test]
    fn closed_all_at_zero() {
        let mut ps = corpus(20);
        assign_arrivals(&mut ps, Arrival::Closed, 1);
        assert!(ps.iter().all(|p| p.arrival_s == 0.0));
        assert_eq!(span(&ps), 0.0);
    }

    #[test]
    fn open_monotone_nondecreasing() {
        let mut ps = corpus(200);
        assign_arrivals(&mut ps, Arrival::Open { rate: 5.0 }, 1);
        for w in ps.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        assert!(span(&ps) > 0.0);
    }

    #[test]
    fn open_rate_approximately_respected() {
        let mut ps = corpus(2000);
        assign_arrivals(&mut ps, Arrival::Open { rate: 10.0 }, 2);
        let mean_gap = span(&ps) / 2000.0;
        assert!((mean_gap - 0.1).abs() < 0.01, "gap={mean_gap}");
    }

    #[test]
    fn slo_assignment_fraction_and_determinism() {
        let mut a = corpus(2000);
        assign_slos(&mut a, 0.4, 7200.0, 11);
        let frac = a.iter().filter(|p| p.slo.is_deferrable()).count() as f64 / 2000.0;
        assert!((frac - 0.4).abs() < 0.05, "frac={frac}");
        assert!(a
            .iter()
            .all(|p| p.slo.deadline_s().map(|d| d == 7200.0).unwrap_or(true)));

        let mut b = corpus(2000);
        assign_slos(&mut b, 0.4, 7200.0, 11);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.slo, y.slo);
        }

        // extremes
        let mut c = corpus(50);
        assign_slos(&mut c, 0.0, 60.0, 1);
        assert!(c.iter().all(|p| !p.slo.is_deferrable()));
        assign_slos(&mut c, 1.0, 60.0, 1);
        assert!(c.iter().all(|p| p.slo.is_deferrable()));
    }

    #[test]
    fn open_deterministic_per_seed() {
        let mut a = corpus(50);
        let mut b = corpus(50);
        assign_arrivals(&mut a, Arrival::Open { rate: 2.0 }, 9);
        assign_arrivals(&mut b, Arrival::Open { rate: 2.0 }, 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s, y.arrival_s);
        }
    }
}
