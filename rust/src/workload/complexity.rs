//! Complexity judge substitute (the paper uses a cloud judge model).
//!
//! The paper's judge "rates expected reasoning depth and token footprint"
//! into CS ∈ [0,1]. We replace it with a deterministic feature scorer
//! over the prompt text + expected output demand:
//!
//! - reasoning-marker density (imperatives like "step by step",
//!   "explain", constraint words like "exactly one", "only if");
//! - generative-demand markers ("write", "story", word-count asks);
//! - token footprint (prompt length + output demand, linear with cap);
//!
//! Weights are calibrated so the paper's Table 1 prompts reproduce their
//! published scores: P1 ≈ 0.47, P2 ≈ 0.39, P3 ≈ 0.08, P4 ≈ 0.07
//! (asserted in canonical.rs tests).

/// Markers indicating multi-step/logical reasoning demand.
const REASONING_MARKERS: [&str; 11] = [
    "step by step",
    "explain",
    "deduc",
    "assign",
    "only if",
    "exactly one",
    "solve",
    "choose the correct",
    "reasoning",
    "logic",
    "prove",
];

/// Markers indicating long-form generation demand.
const GENERATIVE_MARKERS: [&str; 10] = [
    "write",
    "story",
    "words",
    "summar",
    "continue",
    "compose",
    "detailed",
    "function",
    "docstring",
    "twist",
];

const BASE: f64 = 0.06;
const W_REASONING: f64 = 0.22;
const W_GENERATIVE: f64 = 0.07;
const W_FOOTPRINT: f64 = 0.42;
/// Token footprint that counts as "maximal" (saturation cap).
const FOOTPRINT_CAP_TOKENS: f64 = 2000.0;

/// Score a prompt's complexity: CS ∈ [0, 1], higher = harder.
///
/// `output_demand_tokens` is the expected generation length (the paper's
/// judge sees this implicitly as "token footprint").
pub fn score(text: &str, output_demand_tokens: usize) -> f64 {
    let lower = text.to_lowercase();

    let reasoning_hits = REASONING_MARKERS.iter().filter(|m| lower.contains(**m)).count();
    let generative_hits = GENERATIVE_MARKERS.iter().filter(|m| lower.contains(**m)).count();

    // saturating marker terms
    let reasoning = 1.0 - (-0.50 * reasoning_hits as f64).exp();
    let generative = 1.0 - (-0.35 * generative_hits as f64).exp();

    // token footprint: prompt (byte tokens) + output demand, capped
    let footprint_tokens = text.len() as f64 + output_demand_tokens as f64;
    let footprint = (footprint_tokens / FOOTPRINT_CAP_TOKENS).min(1.0);

    let cs = BASE + W_REASONING * reasoning + W_GENERATIVE * generative + W_FOOTPRINT * footprint;
    crate::util::clamp(cs, 0.0, 1.0)
}

/// Complexity bands used in reports and the complexity-aware strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Band {
    /// CS < 0.2 — factual lookups (P3/P4-like).
    Simple,
    /// 0.2 <= CS < 0.45 — moderate tasks.
    Moderate,
    /// CS >= 0.45 — multi-step reasoning / heavy generation.
    Complex,
}

pub fn band(cs: f64) -> Band {
    if cs < 0.2 {
        Band::Simple
    } else if cs < 0.45 {
        Band::Moderate
    } else {
        Band::Complex
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factual_scores_low() {
        let cs = score("What is the boiling point of water at standard atmospheric pressure?", 12);
        assert!(cs < 0.2, "cs={cs}");
        assert_eq!(band(cs), Band::Simple);
    }

    #[test]
    fn reasoning_scores_high() {
        let text = "A group of five friends must each take exactly one task. \
                    Alice hates driving. Assign the tasks and explain your \
                    logical deduction step by step. Solve it with careful reasoning.";
        let cs = score(text, 250);
        // well above any factual lookup, below the footprint-heavy P1
        assert!(cs > 0.35, "cs={cs}");
        let factual = score("Who painted the Mona Lisa?", 10);
        assert!(cs > factual + 0.25);
    }

    #[test]
    fn monotone_in_output_demand() {
        let text = "Summarize this article.";
        assert!(score(text, 400) > score(text, 10));
    }

    #[test]
    fn bounded_in_unit_interval() {
        let huge = "explain solve write story summarize ".repeat(100);
        let cs = score(&huge, 10_000);
        assert!((0.0..=1.0).contains(&cs));
        assert!(score("", 0) >= 0.0);
    }

    #[test]
    fn deterministic() {
        let t = "Write a short story about a clock.";
        assert_eq!(score(t, 500), score(t, 500));
    }

    #[test]
    fn band_edges() {
        assert_eq!(band(0.0), Band::Simple);
        assert_eq!(band(0.2), Band::Moderate);
        assert_eq!(band(0.45), Band::Complex);
        assert_eq!(band(1.0), Band::Complex);
    }
}
