//! Synthetic composite-corpus generator.
//!
//! Reproduces the *marginals* of the paper's ~5000-prompt composite
//! benchmark (DESIGN.md substitution table): category mix weights,
//! per-category log-normal prompt/output token distributions, and
//! complexity scores. Prompt text is synthesized from the category's
//! seed phrase plus deterministic filler so the byte-level token count
//! matches the sampled length — the same text is served verbatim through
//! the PJRT path in real execution mode.

use crate::config::WorkloadConfig;
use crate::util::rng::Rng;

use super::categories::Category;
use super::{complexity, tokenizer, Prompt, SloClass};

/// Mean output demand across the corpus (tokens); devices scale their
/// verbosity relative to this (Prompt::output_tokens_on).
pub const CORPUS_MEAN_OUTPUT_TOKENS: f64 = 95.0;

/// Filler vocabulary for synthetic prompt bodies (content-free but
/// realistic byte statistics).
const FILLER: [&str; 24] = [
    "the", "system", "value", "number", "people", "model", "result", "question",
    "data", "energy", "process", "work", "time", "long", "given", "under",
    "report", "describe", "section", "details", "context", "first", "second", "final",
];

/// A generated corpus: prompts plus bookkeeping for reports.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub prompts: Vec<Prompt>,
    pub seed: u64,
}

impl Corpus {
    /// Generate per the workload config (category filter honoured;
    /// closed-loop arrivals at t=0 — `trace` reassigns arrival times for
    /// open-loop experiments).
    pub fn generate(cfg: &WorkloadConfig) -> Self {
        let cats: Vec<Category> = if cfg.categories.is_empty() {
            Category::ALL.to_vec()
        } else {
            cfg.categories
                .iter()
                .filter_map(|name| Category::parse(name))
                .collect()
        };
        assert!(!cats.is_empty(), "no valid categories selected");
        let weights: Vec<f64> = cats.iter().map(|c| c.profile().weight).collect();

        let mut rng = Rng::new(cfg.seed);
        let prompts = (0..cfg.prompts)
            .map(|i| {
                let cat = cats[rng.choose_weighted(&weights)];
                Self::sample_prompt(i as u64, cat, &mut rng)
            })
            .collect();
        Corpus { prompts, seed: cfg.seed }
    }

    /// Sample one prompt from a category's distributions.
    pub fn sample_prompt(id: u64, cat: Category, rng: &mut Rng) -> Prompt {
        let prof = cat.profile();
        let prompt_tokens =
            (rng.lognormal(prof.prompt_median, prof.prompt_sigma).round() as usize).clamp(12, 4000);
        let output_demand =
            (rng.lognormal(prof.output_median, prof.output_sigma).round() as usize).clamp(4, 2000);

        let text = synth_text(cat, prompt_tokens, rng);
        // judge substitute + category prior + small deterministic jitter
        let scored = complexity::score(&text, output_demand);
        let cs = crate::util::clamp(
            0.55 * scored + 0.45 * prof.base_complexity + rng.normal(0.0, 0.02),
            0.0,
            1.0,
        );

        Prompt {
            id,
            category: cat,
            prompt_tokens: tokenizer::count(&text),
            text,
            output_demand_tokens: output_demand,
            complexity: cs,
            arrival_s: 0.0,
            slo: SloClass::Interactive,
        }
    }

    /// Per-category counts (report support).
    pub fn category_histogram(&self) -> Vec<(Category, usize)> {
        let mut counts: Vec<(Category, usize)> =
            Category::ALL.iter().map(|&c| (c, 0)).collect();
        for p in &self.prompts {
            if let Some(slot) = counts.iter_mut().find(|(c, _)| *c == p.category) {
                slot.1 += 1;
            }
        }
        counts
    }

    /// Mean prompt tokens across the corpus.
    pub fn mean_prompt_tokens(&self) -> f64 {
        if self.prompts.is_empty() {
            return 0.0;
        }
        self.prompts.iter().map(|p| p.prompt_tokens as f64).sum::<f64>()
            / self.prompts.len() as f64
    }

    /// Mean output demand across the corpus.
    pub fn mean_output_demand(&self) -> f64 {
        if self.prompts.is_empty() {
            return 0.0;
        }
        self.prompts.iter().map(|p| p.output_demand_tokens as f64).sum::<f64>()
            / self.prompts.len() as f64
    }
}

/// Synthesize text of ~`target_tokens` bytes starting from the category
/// seed phrase.
fn synth_text(cat: Category, target_tokens: usize, rng: &mut Rng) -> String {
    let mut text = String::with_capacity(target_tokens + 16);
    text.push_str(cat.seed_phrase());
    while text.len() < target_tokens {
        text.push(' ');
        text.push_str(FILLER[rng.below(FILLER.len())]);
    }
    text.truncate(target_tokens.max(cat.seed_phrase().len()));
    // avoid trailing partial-word weirdness mattering anywhere: it's
    // synthetic filler; byte count is what the models consume.
    text
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::util::check::property;

    fn cfg(prompts: usize, seed: u64) -> WorkloadConfig {
        WorkloadConfig {
            prompts,
            seed,
            categories: Vec::new(),
            arrival: crate::config::Arrival::Closed,
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Corpus::generate(&cfg(50, 7));
        let b = Corpus::generate(&cfg(50, 7));
        for (x, y) in a.prompts.iter().zip(&b.prompts) {
            assert_eq!(x.text, y.text);
            assert_eq!(x.output_demand_tokens, y.output_demand_tokens);
            assert!((x.complexity - y.complexity).abs() < 1e-12);
        }
        let c = Corpus::generate(&cfg(50, 8));
        assert!(a.prompts.iter().zip(&c.prompts).any(|(x, y)| x.text != y.text));
    }

    #[test]
    fn corpus_marginals_match_profiles() {
        let corpus = Corpus::generate(&cfg(3000, 42));
        // overall prompt-token mean near the calibration reference (~164
        // from the weighted medians; lognormal mean slightly above)
        let mean_p = corpus.mean_prompt_tokens();
        assert!((120.0..230.0).contains(&mean_p), "mean prompt tokens {mean_p}");
        let mean_o = corpus.mean_output_demand();
        assert!(
            (CORPUS_MEAN_OUTPUT_TOKENS * 0.75..CORPUS_MEAN_OUTPUT_TOKENS * 1.25)
                .contains(&mean_o),
            "mean output demand {mean_o}"
        );
        // every category present, roughly at its weight
        for (cat, count) in corpus.category_histogram() {
            let frac = count as f64 / 3000.0;
            let w = cat.profile().weight;
            assert!(
                (frac - w).abs() < 0.03,
                "{}: frac {frac} vs weight {w}",
                cat.name()
            );
        }
    }

    #[test]
    fn complexity_tracks_category_difficulty() {
        let corpus = Corpus::generate(&cfg(3000, 1));
        let mean_cs = |c: Category| {
            let xs: Vec<f64> = corpus
                .prompts
                .iter()
                .filter(|p| p.category == c)
                .map(|p| p.complexity)
                .collect();
            xs.iter().sum::<f64>() / xs.len() as f64
        };
        // reasoning/code-heavy categories must outrank factual ones
        assert!(mean_cs(Category::Gsm8k) > mean_cs(Category::ArcChallenge));
        assert!(mean_cs(Category::PythonCode) > mean_cs(Category::Squad));
        assert!(mean_cs(Category::ArxivSumm) > mean_cs(Category::Squad));
    }

    #[test]
    fn category_filter_respected() {
        let mut c = cfg(100, 3);
        c.categories = vec!["squad".into(), "gsm8k".into()];
        let corpus = Corpus::generate(&c);
        assert!(corpus
            .prompts
            .iter()
            .all(|p| matches!(p.category, Category::Squad | Category::Gsm8k)));
    }

    #[test]
    fn prompt_text_token_count_consistent() {
        property("text length == prompt_tokens", 64, |rng| {
            let cat = *rng.choose(&Category::ALL);
            let p = Corpus::sample_prompt(0, cat, rng);
            if p.prompt_tokens == p.text.len() {
                Ok(())
            } else {
                Err(format!("{} != {}", p.prompt_tokens, p.text.len()))
            }
        });
    }

    #[test]
    fn complexity_in_unit_interval() {
        property("cs in [0,1]", 128, |rng| {
            let cat = *rng.choose(&Category::ALL);
            let p = Corpus::sample_prompt(0, cat, rng);
            if (0.0..=1.0).contains(&p.complexity) {
                Ok(())
            } else {
                Err(format!("cs={}", p.complexity))
            }
        });
    }
}
