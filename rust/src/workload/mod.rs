//! Workload substrate: the paper's composite prompt benchmark, rebuilt.
//!
//! The paper samples 500 prompts from a ~5000-prompt composite of eight
//! public datasets (GSM8K, SQuAD, DialogSum, python-code-instructions,
//! ARC-Challenge, arXiv summarization, DailyDialog, CNN/DailyMail) and
//! scores each with a cloud judge model (complexity score CS ∈ [0,1]).
//! We cannot ship those datasets, so [`generator`] synthesizes a corpus
//! with the same *marginals the routing layer consumes*: category mix,
//! per-category prompt/output token distributions, and CS. The judge is
//! replaced by the deterministic feature scorer in [`complexity`]
//! (calibrated to reproduce the paper's P1–P4 scores).

pub mod canonical;
pub mod categories;
pub mod complexity;
pub mod generator;
pub mod tokenizer;
pub mod trace;

pub use categories::Category;
pub use generator::Corpus;

/// Service-level objective class of a request — the temporal-shifting
/// contract (see `grid` module docs §Deferral model).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SloClass {
    /// Latency-sensitive: route and execute the moment it arrives.
    Interactive,
    /// Batch-style: may be held and executed any time within
    /// `deadline_s` seconds of arrival (completion deadline).
    Deferrable { deadline_s: f64 },
}

impl SloClass {
    pub fn is_deferrable(&self) -> bool {
        matches!(self, SloClass::Deferrable { .. })
    }

    /// Completion deadline relative to arrival, if any.
    pub fn deadline_s(&self) -> Option<f64> {
        match self {
            SloClass::Interactive => None,
            SloClass::Deferrable { deadline_s } => Some(*deadline_s),
        }
    }
}

/// One inference request flowing through the system.
#[derive(Debug, Clone)]
pub struct Prompt {
    /// Stable id (generation order).
    pub id: u64,
    pub category: Category,
    /// Synthetic prompt text (tokenizable; used verbatim in real mode).
    pub text: String,
    /// Prompt length in tokens (byte-level tokenizer).
    pub prompt_tokens: usize,
    /// Model-independent output-length demand in tokens; devices scale
    /// it by their model's verbosity (Table 2: the 1B model averages
    /// ~148 output tokens, the 12B ~70 for the same prompts).
    pub output_demand_tokens: usize,
    /// Complexity score CS ∈ [0,1] from the judge substitute.
    pub complexity: f64,
    /// Arrival time in seconds (0.0 for the paper's closed-loop runs).
    pub arrival_s: f64,
    /// SLO class; `Interactive` unless `trace::assign_slos` marks the
    /// prompt deferrable.
    pub slo: SloClass,
}

impl Prompt {
    /// Output tokens this prompt will generate on a device whose model
    /// has `output_median_tokens` verbosity (see generator docs).
    pub fn output_tokens_on(&self, output_median_tokens: f64) -> usize {
        let scale = output_median_tokens / generator::CORPUS_MEAN_OUTPUT_TOKENS;
        ((self.output_demand_tokens as f64 * scale).round() as usize).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_scaling_matches_device_verbosity() {
        let p = Prompt {
            id: 0,
            category: Category::Gsm8k,
            text: "x".into(),
            prompt_tokens: 10,
            output_demand_tokens: 90,
            complexity: 0.5,
            arrival_s: 0.0,
            slo: SloClass::Interactive,
        };
        let jetson = p.output_tokens_on(148.0);
        let ada = p.output_tokens_on(69.6);
        assert!(jetson > ada, "1B model must be more verbose");
        assert!(jetson >= 1 && ada >= 1);
    }

    #[test]
    fn slo_class_helpers() {
        assert!(!SloClass::Interactive.is_deferrable());
        assert_eq!(SloClass::Interactive.deadline_s(), None);
        let d = SloClass::Deferrable { deadline_s: 3600.0 };
        assert!(d.is_deferrable());
        assert_eq!(d.deadline_s(), Some(3600.0));
    }
}
