//! Calibration anchors fitted to the paper's Table 2 measurements.
//!
//! The paper's entire methodology is "benchmarking-driven": it measures
//! TTFT/TPOT/E2E/energy per (device, batch) on its physical testbed and
//! routes prompts using those measurements. We do not have the hardware
//! (repro band 0/5), so this module *is* the substitute testbed: every
//! anchor below is back-derived from Table 2 of the paper, and both the
//! simulator (ground truth) and the router's cost estimator (what the
//! paper calls "benchmarking information") read from here.
//!
//! Derivations (Table 2, per-prompt averages):
//!
//! | device | b | TTFT | TPOT  | E2E   | tok  | kWh      | avg W            |
//! |--------|---|------|-------|-------|------|----------|------------------|
//! | Jetson | 1 | 0.36 | 0.061 | 13.06 | 148  | 1.79e-5  | 64.4 J/13.06=4.9 |
//! | Jetson | 4 | 1.13 | 0.063 | 15.08 | 149  | 4.89e-6  | 70.4 J/15.08=4.7 |
//! | Jetson | 8 | 4.87 | 0.057 | 14.12 | 136  | 5.12e-6  | 147 J/14.12=10.4 |
//! | Ada    | 1 | 0.26 | 0.030 |  3.39 | 69.6 | 6.35e-5  | 229 J/3.39 =67.4 |
//! | Ada    | 4 | 12.07| 0.020 | 14.58 | 56.8 | 5.05e-5  | 727 J/14.58=49.9 |
//! | Ada    | 8 | 24.00| 0.030 | 26.82 | 64.0 | 5.73e-5  | 1650 J/26.8=61.5 |
//!
//! Carbon/energy ratios are constant at ≈69 gCO2e/kWh on both devices
//! (the Austrian grid), which fixes the cluster's carbon intensity.
//!
//! TTFT grows superlinearly with batch because the paper's Ollama stack
//! serializes prefill across batch members; we keep that behaviour (it
//! is what the routing strategies saw) and expose it as per-batch TTFT
//! anchors scaled by relative prompt length.

use crate::config::DeviceKind;

/// Reference prompt length the Table-2 averages correspond to. The
/// composite corpus averages ~150 prompt tokens; TTFT scales ∝ prompt
/// tokens around this reference.
pub const REF_PROMPT_TOKENS: f64 = 150.0;

/// Reference output length per device (Table 2 token counts); decode
/// time scales ∝ output tokens around these.
pub const REF_OUTPUT_TOKENS_JETSON: f64 = 148.0;
pub const REF_OUTPUT_TOKENS_ADA: f64 = 69.6;

/// Latency calibration for one device kind.
#[derive(Debug, Clone)]
pub struct LatencyCalibration {
    /// (batch, seconds-to-first-token at REF_PROMPT_TOKENS) anchors.
    pub ttft_anchors: Vec<(f64, f64)>,
    /// (batch, seconds per output token) anchors.
    pub tpot_anchors: Vec<(f64, f64)>,
    /// (batch, seconds) anchors for the fixed per-batch dispatch/session
    /// overhead (model wake, sampler setup, response assembly) — the
    /// non-token-proportional residue of Table 2's E2E column. It is NOT
    /// monotone in batch on the paper's testbed (Ollama reuses sessions
    /// differently per batch size); we take the measurements as-is.
    pub overhead_anchors: Vec<(f64, f64)>,
    /// Dispatch floor inside TTFT (connection + queue pickup).
    pub dispatch_s: f64,
}

/// Fraction of the TTFT anchor that scales with prompt length; the rest
/// is fixed per-sequence session work (attention setup, cache alloc,
/// sampler init) that the serialized-prefill stack pays regardless of
/// length. Without this floor, homogeneous short-prompt benchmarks
/// underestimate TTFT badly vs mixed traffic.
pub const TTFT_LENGTH_FRACTION: f64 = 0.5;

impl LatencyCalibration {
    /// TTFT for a batch whose mean prompt length is `mean_prompt_tokens`.
    pub fn ttft(&self, batch: usize, mean_prompt_tokens: f64) -> f64 {
        let anchor = crate::util::interp(&self.ttft_anchors, batch as f64).max(self.dispatch_s);
        let rel = mean_prompt_tokens / REF_PROMPT_TOKENS;
        let scale = (1.0 - TTFT_LENGTH_FRACTION) + TTFT_LENGTH_FRACTION * rel;
        (self.dispatch_s + (anchor - self.dispatch_s) * scale).max(1e-4)
    }

    /// Seconds per output token at this batch size.
    pub fn tpot(&self, batch: usize) -> f64 {
        crate::util::interp(&self.tpot_anchors, batch as f64).max(1e-4)
    }

    /// Fixed session overhead for this batch size (clamped: linear
    /// extrapolation beyond the anchors must not go negative).
    pub fn overhead(&self, batch: usize) -> f64 {
        crate::util::interp(&self.overhead_anchors, batch as f64).max(0.25)
    }
}

/// Saturation / instability calibration (the paper's batch-8 Jetson
/// behaviour: "errors due to memory saturation", retries, degraded
/// accuracy).
#[derive(Debug, Clone)]
pub struct SaturationCalibration {
    /// Latency multiplier per unit of memory-saturation overshoot
    /// (MemoryModel::saturation output).
    pub latency_penalty_per_sat: f64,
    /// Energy multiplier per unit of overshoot (thrashing costs joules).
    pub energy_penalty_per_sat: f64,
    /// Failure (OOM/retry) probability per unit of overshoot, clamped.
    pub failure_prob_per_sat: f64,
    /// Time lost to a failed attempt before the retry, seconds.
    pub retry_penalty_s: f64,
}

/// Full calibration bundle for one device kind.
#[derive(Debug, Clone)]
pub struct DeviceCalibration {
    pub latency: LatencyCalibration,
    pub idle_w: f64,
    /// (batch, average active watts) anchors.
    pub power_anchors: Vec<(f64, f64)>,
    pub saturation: SaturationCalibration,
    /// Memory model parameters (paper-scale checkpoint):
    pub weights_gb: f64,
    pub kv_mb_per_token: f64,
    pub activation_mb_per_seq: f64,
    pub saturation_start: f64,
    /// Typical output-token median for this device's model (Table 2) —
    /// the 1B model rambles (~148 tokens), the 12B is terse (~70).
    pub output_median_tokens: f64,
}

/// Calibration for a device kind, straight from the Table-2 derivation.
pub fn for_kind(kind: DeviceKind) -> DeviceCalibration {
    match kind {
        DeviceKind::Jetson => DeviceCalibration {
            latency: LatencyCalibration {
                ttft_anchors: vec![(1.0, 0.36), (4.0, 1.13), (8.0, 4.87)],
                tpot_anchors: vec![(1.0, 0.061), (4.0, 0.063), (8.0, 0.057)],
                // E2E residue per batch: b1: 13.06-0.36-148*0.061 = 3.67;
                // b4: 15.08-1.13-149*0.063 = 4.56; b8: 14.12-4.87-136*0.057
                // = 1.50 (the Jetson's Ollama session cost is not monotone
                // in batch — measured, taken as-is)
                overhead_anchors: vec![(1.0, 3.67), (4.0, 4.56), (8.0, 1.50)],
                dispatch_s: 0.05,
            },
            idle_w: 1.5,
            power_anchors: vec![(1.0, 4.9), (4.0, 4.7), (8.0, 10.4)],
            // The Table-2 power/overhead anchors already embed the
            // *typical* batch-8 pressure; these penalties only price the
            // overshoot beyond it (long-output batches, batch > 8).
            saturation: SaturationCalibration {
                latency_penalty_per_sat: 0.5,
                energy_penalty_per_sat: 0.4,
                failure_prob_per_sat: 0.30,
                retry_penalty_s: 6.0,
            },
            weights_gb: 1.6,
            kv_mb_per_token: 0.75,
            activation_mb_per_seq: 450.0,
            saturation_start: 0.85,
            output_median_tokens: REF_OUTPUT_TOKENS_JETSON,
        },
        DeviceKind::Ada => DeviceCalibration {
            latency: LatencyCalibration {
                ttft_anchors: vec![(1.0, 0.26), (4.0, 12.07), (8.0, 24.0)],
                tpot_anchors: vec![(1.0, 0.030), (4.0, 0.020), (8.0, 0.030)],
                // b1: 3.39-0.26-69.6*0.03 = 1.04; b4: 14.58-12.07-
                // 56.83*0.02 = 1.37; b8: 26.82-24.0-63.97*0.03 = 0.90
                overhead_anchors: vec![(1.0, 1.04), (4.0, 1.37), (8.0, 0.90)],
                dispatch_s: 0.05,
            },
            idle_w: 7.0,
            power_anchors: vec![(1.0, 67.4), (4.0, 49.9), (8.0, 61.5)],
            saturation: SaturationCalibration {
                latency_penalty_per_sat: 0.3,
                energy_penalty_per_sat: 0.3,
                failure_prob_per_sat: 0.10,
                retry_penalty_s: 4.0,
            },
            // Gemma-3-12B-qat ~ 8.1 GB resident on the 16 GB card
            weights_gb: 8.9,
            kv_mb_per_token: 0.55,
            activation_mb_per_seq: 256.0,
            saturation_start: 0.85,
            output_median_tokens: REF_OUTPUT_TOKENS_ADA,
        },
        DeviceKind::Cloud => DeviceCalibration {
            latency: LatencyCalibration {
                // provider-side prefill is effectively instant at edge
                // scale; TTFT dominated by dispatch + queueing
                ttft_anchors: vec![(1.0, 0.9), (4.0, 1.1), (8.0, 1.3)],
                // Gemini-Flash-class decode ~ 125 tok/s
                tpot_anchors: vec![(1.0, 0.008), (4.0, 0.008), (8.0, 0.008)],
                overhead_anchors: vec![(1.0, 0.55), (8.0, 0.55)],
                dispatch_s: 0.35,
            },
            // Cloud power/carbon are the provider's; the paper does not
            // report them (Fig. 2 covers edge models only). We attribute
            // an effective marginal draw for completeness.
            idle_w: 0.0,
            power_anchors: vec![(1.0, 400.0), (8.0, 400.0)],
            saturation: SaturationCalibration {
                latency_penalty_per_sat: 0.0,
                energy_penalty_per_sat: 0.0,
                failure_prob_per_sat: 0.0,
                retry_penalty_s: 0.0,
            },
            weights_gb: 0.0,
            kv_mb_per_token: 0.0,
            activation_mb_per_seq: 0.0,
            saturation_start: 1.0,
            output_median_tokens: 60.0,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jetson_anchors_reproduce_table2_e2e() {
        let c = for_kind(DeviceKind::Jetson);
        // b=1 at reference prompt/output: TTFT + tok*TPOT + overhead ≈ 13.06
        let e2e = c.latency.ttft(1, REF_PROMPT_TOKENS)
            + REF_OUTPUT_TOKENS_JETSON * c.latency.tpot(1)
            + c.latency.overhead(1);
        assert!((e2e - 13.06).abs() < 0.05, "e2e={e2e}");
    }

    #[test]
    fn ada_anchors_reproduce_table2_e2e() {
        let c = for_kind(DeviceKind::Ada);
        let e2e = c.latency.ttft(1, REF_PROMPT_TOKENS)
            + REF_OUTPUT_TOKENS_ADA * c.latency.tpot(1)
            + c.latency.overhead(1);
        assert!((e2e - 3.39).abs() < 0.05, "e2e={e2e}");
    }

    #[test]
    fn ttft_scales_with_prompt_length() {
        let c = for_kind(DeviceKind::Jetson);
        let short = c.latency.ttft(1, 20.0);
        let long = c.latency.ttft(1, 400.0);
        // half the anchor is fixed per-sequence work, so 20x the prompt
        // gives ~2.7x the TTFT
        assert!(long > short * 2.0, "short={short} long={long}");
    }

    #[test]
    fn ttft_grows_with_batch() {
        for kind in [DeviceKind::Jetson, DeviceKind::Ada] {
            let c = for_kind(kind);
            let t1 = c.latency.ttft(1, REF_PROMPT_TOKENS);
            let t4 = c.latency.ttft(4, REF_PROMPT_TOKENS);
            let t8 = c.latency.ttft(8, REF_PROMPT_TOKENS);
            assert!(t1 < t4 && t4 < t8, "{kind:?}: {t1} {t4} {t8}");
        }
    }

    #[test]
    fn jetson_cheaper_per_token_than_ada_in_energy() {
        // The core sustainability asymmetry: Jetson ~5 W vs Ada ~60 W,
        // TPOT only ~2x worse -> Jetson wins energy per token.
        let j = for_kind(DeviceKind::Jetson);
        let a = for_kind(DeviceKind::Ada);
        let j_j_per_tok = j.power_anchors[0].1 * j.latency.tpot(1);
        let a_j_per_tok = a.power_anchors[0].1 * a.latency.tpot(1);
        assert!(j_j_per_tok < a_j_per_tok / 3.0);
    }

    #[test]
    fn cloud_fast_decode_slow_dispatch() {
        let c = for_kind(DeviceKind::Cloud);
        assert!(c.latency.tpot(1) < 0.01);
        assert!(c.latency.ttft(1, 10.0) > 0.3); // dispatch floor
    }
}
