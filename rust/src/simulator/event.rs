//! Deterministic discrete-event queue: virtual clock + stable ordering.
//!
//! Cluster simulations (open-loop serving, ablations) schedule events at
//! future virtual times and pop them in (time, insertion-order) order,
//! so ties never depend on heap internals and whole runs replay
//! bit-identically.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event scheduled at a virtual time.
#[derive(Debug, Clone)]
pub struct Scheduled<E> {
    pub at: f64,
    pub seq: u64,
    pub event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert so earliest time pops first,
        // breaking ties by insertion order (lower seq first).
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Priority queue of events over a virtual clock.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    next_seq: u64,
    now: f64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0, now: 0.0 }
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `event` at absolute virtual time `at`. Scheduling in the
    /// past (before `now`) is clamped to `now` — a late event fires
    /// immediately rather than rewinding the clock.
    pub fn push(&mut self, at: f64, event: E) {
        assert!(at.is_finite(), "non-finite event time");
        let at = at.max(self.now);
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Scheduled { at, seq, event });
    }

    /// Schedule relative to now.
    pub fn push_after(&mut self, delay: f64, event: E) {
        self.push(self.now + delay.max(0.0), event);
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<Scheduled<E>> {
        let next = self.heap.pop()?;
        self.now = next.at;
        Some(next)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|s| s.event)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(q.now(), 3.0);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(1.0, 2);
        q.push(1.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|s| s.event)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_monotone_even_for_late_pushes() {
        let mut q = EventQueue::new();
        q.push(5.0, "x");
        q.pop();
        q.push(1.0, "late"); // clamped to now=5
        let e = q.pop().unwrap();
        assert_eq!(e.at, 5.0);
        assert_eq!(q.now(), 5.0);
    }

    #[test]
    fn push_after_is_relative() {
        let mut q = EventQueue::new();
        q.push(2.0, "first");
        q.pop();
        q.push_after(3.0, "second");
        assert_eq!(q.pop().unwrap().at, 5.0);
    }

    #[test]
    fn len_and_empty() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.is_empty());
        q.push(1.0, ());
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
    }

    #[test]
    #[should_panic]
    fn rejects_nan_times() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }
}
