//! Batch execution timing + energy on a calibrated device.
//!
//! Maps a batch's *work* (per-sequence prompt/output token counts) to
//! the wallclock and energy the paper's hardware exhibits:
//!
//! ```text
//! TTFT(B, p̄)   = dispatch + serialized-prefill anchor scaled by p̄
//! decode        = max_i(out_i) · TPOT(B) · (1 + sat·latency_penalty)
//! total         = TTFT + decode + overhead + failure retries
//! energy        = activeW(B) · total · (1 + sat·energy_penalty)
//! ```
//!
//! Saturation comes from the device memory model over the batch's
//! longest (prompt+output) sequence; failures from [`super::failure`].
//! With `rng = None` the failure chain is evaluated in expectation
//! (deterministic, used by the table benches); with `Some(rng)` it is
//! sampled (serving loop / failure-injection tests).

use crate::cluster::DeviceProfile;
use crate::util::rng::Rng;

use super::failure::{self, FailureOutcome, FailurePolicy};

/// The work content of one batch: per-sequence token counts.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchWork {
    pub prompt_tokens: Vec<usize>,
    pub output_tokens: Vec<usize>,
}

impl BatchWork {
    pub fn new(prompt_tokens: Vec<usize>, output_tokens: Vec<usize>) -> Self {
        assert_eq!(prompt_tokens.len(), output_tokens.len(), "ragged batch work");
        assert!(!prompt_tokens.is_empty(), "empty batch");
        BatchWork { prompt_tokens, output_tokens }
    }

    pub fn batch_size(&self) -> usize {
        self.prompt_tokens.len()
    }

    pub fn mean_prompt_tokens(&self) -> f64 {
        self.prompt_tokens.iter().sum::<usize>() as f64 / self.prompt_tokens.len() as f64
    }

    pub fn max_output_tokens(&self) -> usize {
        self.output_tokens.iter().copied().max().unwrap_or(0)
    }

    /// Longest total sequence (prompt + output) — the KV high-water mark.
    pub fn max_seq_tokens(&self) -> usize {
        self.prompt_tokens
            .iter()
            .zip(&self.output_tokens)
            .map(|(p, o)| p + o)
            .max()
            .unwrap_or(0)
    }

    pub fn total_output_tokens(&self) -> usize {
        self.output_tokens.iter().sum()
    }
}

/// Simulated execution result for one batch.
#[derive(Debug, Clone)]
pub struct BatchTiming {
    /// Time to first token (prefill completion), seconds.
    pub ttft_s: f64,
    /// Decode phase duration (longest sequence), seconds.
    pub decode_s: f64,
    /// End-to-end batch occupancy on the device, seconds (incl.
    /// overhead and retry time).
    pub total_s: f64,
    /// Per-sequence completion offsets from batch start, seconds.
    pub seq_done_s: Vec<f64>,
    /// Memory saturation overshoot during this batch.
    pub saturation: f64,
    /// Active energy consumed, kWh (incl. saturation penalty).
    pub energy_kwh: f64,
    /// Failure-injection outcome.
    pub failure: FailureOutcome,
}

impl BatchTiming {
    /// Average seconds per output token across the batch (the paper's
    /// TPOT metric as measured, incl. penalties).
    pub fn measured_tpot(&self, work: &BatchWork) -> f64 {
        let toks = work.max_output_tokens().max(1) as f64;
        self.decode_s / toks
    }

    /// Batch throughput in output tokens/second (paper's Tokens/s).
    pub fn throughput_tps(&self, work: &BatchWork) -> f64 {
        work.total_output_tokens() as f64 / self.total_s.max(1e-9)
    }
}

/// Simulate one batch on a device (default [`FailurePolicy`]).
pub fn simulate_batch(dev: &DeviceProfile, work: &BatchWork, rng: Option<&mut Rng>) -> BatchTiming {
    simulate_batch_with(dev, work, rng, &FailurePolicy::default())
}

/// Simulate one batch on a device under an explicit retry policy.
pub fn simulate_batch_with(
    dev: &DeviceProfile,
    work: &BatchWork,
    rng: Option<&mut Rng>,
    policy: &FailurePolicy,
) -> BatchTiming {
    let b = work.batch_size();
    let sat = dev.memory.saturation(b, work.max_seq_tokens());

    let ttft = dev.latency.ttft(b, work.mean_prompt_tokens());
    let tpot = dev.latency.tpot(b);
    let sat_latency = 1.0 + sat * dev.saturation.latency_penalty_per_sat;
    let decode = work.max_output_tokens() as f64 * tpot * sat_latency;

    let failure = match rng {
        Some(r) => failure::sample_with(dev, sat, b, r, policy),
        None => failure::expected_with(dev, sat, b, policy),
    };

    let overhead = dev.latency.overhead(b);
    let total = ttft + decode + overhead + failure.extra_time_s;

    // per-sequence completion: prefill completes for everyone at TTFT
    // (serialized prefill, first tokens stream together), then each
    // sequence finishes after its own decode run
    let seq_done_s = work
        .output_tokens
        .iter()
        .map(|&o| ttft + o as f64 * tpot * sat_latency + overhead)
        .collect();

    let sat_energy = 1.0 + sat * dev.saturation.energy_penalty_per_sat;
    let energy_kwh = dev.power.active_energy_kwh(b, total) * sat_energy;

    BatchTiming { ttft_s: ttft, decode_s: decode, total_s: total, seq_done_s, saturation: sat, energy_kwh, failure }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::calibration::{
        REF_OUTPUT_TOKENS_ADA, REF_OUTPUT_TOKENS_JETSON, REF_PROMPT_TOKENS,
    };
    use crate::util::check::{close, property};

    fn ref_work(b: usize, prompt: f64, out: f64) -> BatchWork {
        BatchWork::new(vec![prompt as usize; b], vec![out as usize; b])
    }

    #[test]
    fn jetson_b1_reproduces_table2_row() {
        let dev = crate::cluster::DeviceProfile::jetson();
        let w = ref_work(1, REF_PROMPT_TOKENS, REF_OUTPUT_TOKENS_JETSON);
        let t = simulate_batch(&dev, &w, None);
        close(t.ttft_s, 0.36, 0.02).unwrap();
        close(t.total_s, 13.06, 0.02).unwrap();
        close(t.energy_kwh, 1.79e-5, 0.05).unwrap();
        assert_eq!(t.failure, FailureOutcome::CLEAN);
    }

    #[test]
    fn ada_b1_reproduces_table2_row() {
        let dev = crate::cluster::DeviceProfile::ada();
        let w = ref_work(1, REF_PROMPT_TOKENS, REF_OUTPUT_TOKENS_ADA);
        let t = simulate_batch(&dev, &w, None);
        close(t.ttft_s, 0.26, 0.02).unwrap();
        close(t.total_s, 3.39, 0.02).unwrap();
        close(t.energy_kwh, 6.35e-5, 0.05).unwrap();
    }

    #[test]
    fn ada_b4_b8_ttft_growth() {
        let dev = crate::cluster::DeviceProfile::ada();
        let t4 = simulate_batch(&dev, &ref_work(4, REF_PROMPT_TOKENS, 57.0), None);
        let t8 = simulate_batch(&dev, &ref_work(8, REF_PROMPT_TOKENS, 64.0), None);
        close(t4.ttft_s, 12.07, 0.02).unwrap();
        close(t8.ttft_s, 24.0, 0.02).unwrap();
    }

    #[test]
    fn per_prompt_energy_falls_with_batching_on_jetson() {
        // the paper's amortization effect (Table 2 energy column)
        let dev = crate::cluster::DeviceProfile::jetson();
        let e1 = simulate_batch(&dev, &ref_work(1, 150.0, 148.0), None).energy_kwh / 1.0;
        let e4 = simulate_batch(&dev, &ref_work(4, 150.0, 148.0), None).energy_kwh / 4.0;
        assert!(e4 < e1 * 0.5, "e1={e1} e4={e4}");
    }

    #[test]
    fn jetson_batch8_long_outputs_saturate_and_fail() {
        let dev = crate::cluster::DeviceProfile::jetson();
        // 8 × (300 prompt + 700 output) ≈ 1000-token sequences
        let w = ref_work(8, 300.0, 700.0);
        let t = simulate_batch(&dev, &w, None);
        assert!(t.saturation > 0.0, "sat={}", t.saturation);
        assert!(t.failure.retries > 0.0);
        assert!(t.failure.errors > 0.0);
        // and the same work on the Ada is stable
        let ada = crate::cluster::DeviceProfile::ada();
        let ta = simulate_batch(&ada, &w, None);
        assert!(ta.saturation < t.saturation);
    }

    #[test]
    fn seq_done_bounded_by_total() {
        property("per-seq completion <= batch total", 64, |rng| {
            let dev = if rng.chance(0.5) {
                crate::cluster::DeviceProfile::jetson()
            } else {
                crate::cluster::DeviceProfile::ada()
            };
            let b = rng.below(8) + 1;
            let w = BatchWork::new(
                (0..b).map(|_| rng.below(400) + 10).collect(),
                (0..b).map(|_| rng.below(300) + 1).collect(),
            );
            let t = simulate_batch(&dev, &w, None);
            for &d in &t.seq_done_s {
                if d > t.total_s + 1e-9 {
                    return Err(format!("seq done {d} > total {}", t.total_s));
                }
            }
            if t.seq_done_s.iter().cloned().fold(f64::MIN, f64::max) > t.total_s + 1e-9 {
                return Err("max seq beyond total".into());
            }
            Ok(())
        });
    }

    #[test]
    fn timing_positive_and_monotone_in_output() {
        property("timing sane", 64, |rng| {
            let dev = crate::cluster::DeviceProfile::ada();
            let b = rng.below(8) + 1;
            let p = rng.below(300) + 20;
            let o1 = rng.below(100) + 1;
            let o2 = o1 + rng.below(200) + 10;
            let t1 = simulate_batch(&dev, &BatchWork::new(vec![p; b], vec![o1; b]), None);
            let t2 = simulate_batch(&dev, &BatchWork::new(vec![p; b], vec![o2; b]), None);
            if t1.total_s <= 0.0 || t1.energy_kwh <= 0.0 {
                return Err("non-positive timing".into());
            }
            if t2.decode_s <= t1.decode_s {
                return Err("decode not monotone in output tokens".into());
            }
            Ok(())
        });
    }

    #[test]
    fn measured_metrics_helpers() {
        let dev = crate::cluster::DeviceProfile::ada();
        let w = ref_work(2, 100.0, 50.0);
        let t = simulate_batch(&dev, &w, None);
        assert!(t.measured_tpot(&w) > 0.0);
        let tps = t.throughput_tps(&w);
        assert!((tps - 100.0 / t.total_s).abs() < 1e-9);
    }
}
