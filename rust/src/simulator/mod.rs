//! Calibrated device simulator (the substitute testbed).
//!
//! The paper measures batches on physical Jetson/Ada devices; we do not
//! have them (repro band 0/5), so this module maps *real work* — token
//! counts produced by the PJRT runtime or sampled from the workload
//! model — onto the wallclock, energy and failure behaviour those
//! devices exhibit, using the Table-2 anchors in [`calibration`].
//!
//! - [`latency`] — batch execution timing (TTFT, decode, overhead,
//!   saturation penalties) + energy integration;
//! - [`failure`] — the Jetson batch-8 instability: OOM/retry injection
//!   with latency/energy/accuracy consequences (policy-configurable
//!   via `[serving.failure]`), plus device churn ([`ChurnSchedule`]:
//!   scripted outage windows or stochastic MTBF/MTTR sampling);
//! - [`event`] — a deterministic discrete-event queue driving cluster
//!   simulations (virtual clock, stable tie-breaking).

pub mod calibration;
pub mod event;
pub mod failure;
pub mod latency;

pub use event::EventQueue;
pub use failure::{ChurnSchedule, FailurePolicy, OutageWindow};
pub use latency::{simulate_batch, simulate_batch_with, BatchTiming, BatchWork};
