//! Failure injection: memory-saturation instability (paper §3) and
//! device churn.
//!
//! The paper observes that batch 8 on the 8 GB Jetson "introduces
//! instability and accuracy degradation ... errors due to memory
//! saturation". We model it as an OOM/retry process driven by the
//! memory model's saturation overshoot:
//!
//! - with probability `failure_prob_per_sat × saturation` an attempt
//!   fails (clamped at the policy's `max_fail_prob`);
//! - each failed attempt costs `retry_penalty_s` wallclock (and the
//!   corresponding active energy) before the retry;
//! - a request that fails `max_attempts` times is recorded as an error
//!   (the paper's "accuracy degradation" shows up as our error rate).
//!
//! Two evaluation modes:
//! - [`expected`] — deterministic expected-value penalties (used by the
//!   table benches so rows replay exactly);
//! - [`sample`] — stochastic injection from the experiment RNG (used by
//!   failure-injection tests and the serving loop).
//!
//! The retry chain is parameterized by a [`FailurePolicy`]
//! (`[serving.failure]` in the TOML config); its [`Default`]
//! reproduces the historic hard-coded constants bit-for-bit.
//!
//! Beyond per-batch OOM, [`ChurnSchedule`] models *device churn*:
//! whole devices going Down and coming back. Outages are either
//! scripted windows (deterministic — pinned tests and bench replay) or
//! stochastically sampled from MTBF/MTTR via the experiment [`Rng`].
//! The schedule is a pure timeline: planes query
//! [`ChurnSchedule::state_at`] / [`ChurnSchedule::transitions`] and
//! drive their own `cluster::health::HealthMask` from it.

use anyhow::{anyhow, bail, Result};

use crate::cluster::health::HealthState;
use crate::cluster::DeviceProfile;
use crate::util::rng::Rng;

/// Retries after which the request is declared failed.
pub const MAX_ATTEMPTS: usize = 3;
/// Hard cap on per-attempt failure probability.
pub const MAX_FAIL_PROB: f64 = 0.9;

/// Configurable OOM-retry policy (`[serving.failure]`). The default
/// reproduces the historic [`MAX_ATTEMPTS`] / [`MAX_FAIL_PROB`]
/// constants bit-for-bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailurePolicy {
    /// Retries after which the request is declared failed.
    pub max_attempts: usize,
    /// Hard cap on per-attempt failure probability.
    pub max_fail_prob: f64,
}

impl Default for FailurePolicy {
    fn default() -> Self {
        FailurePolicy { max_attempts: MAX_ATTEMPTS, max_fail_prob: MAX_FAIL_PROB }
    }
}

impl FailurePolicy {
    /// Validate invariants: at least one attempt, probability cap in
    /// [0, 1).
    pub fn validate(&self) -> Result<()> {
        if self.max_attempts == 0 {
            bail!("[serving.failure] max_attempts must be >= 1");
        }
        if !self.max_fail_prob.is_finite() || !(0.0..1.0).contains(&self.max_fail_prob) {
            bail!(
                "[serving.failure] max_fail_prob must be in [0, 1), got {}",
                self.max_fail_prob
            );
        }
        Ok(())
    }
}

/// Result of failure evaluation for one batch attempt chain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureOutcome {
    /// Number of failed attempts before success (0 = clean).
    pub retries: f64,
    /// Extra wallclock spent on failed attempts, seconds.
    pub extra_time_s: f64,
    /// Probability-weighted count of requests that exhausted retries
    /// (deterministic mode) or 0/1 (sampled mode), per batch.
    pub errors: f64,
}

impl FailureOutcome {
    pub const CLEAN: FailureOutcome =
        FailureOutcome { retries: 0.0, extra_time_s: 0.0, errors: 0.0 };
}

/// Per-attempt failure probability for a device at a saturation level.
pub fn fail_prob(dev: &DeviceProfile, saturation: f64) -> f64 {
    fail_prob_with(dev, saturation, &FailurePolicy::default())
}

/// [`fail_prob`] under an explicit policy.
pub fn fail_prob_with(dev: &DeviceProfile, saturation: f64, policy: &FailurePolicy) -> f64 {
    (dev.saturation.failure_prob_per_sat * saturation).clamp(0.0, policy.max_fail_prob)
}

/// Deterministic expected-value outcome (geometric retry chain).
pub fn expected(dev: &DeviceProfile, saturation: f64, batch_size: usize) -> FailureOutcome {
    expected_with(dev, saturation, batch_size, &FailurePolicy::default())
}

/// [`expected`] under an explicit policy.
pub fn expected_with(
    dev: &DeviceProfile,
    saturation: f64,
    batch_size: usize,
    policy: &FailurePolicy,
) -> FailureOutcome {
    let p = fail_prob_with(dev, saturation, policy);
    if p <= 0.0 {
        return FailureOutcome::CLEAN;
    }
    // expected failed attempts, capped at max_attempts:
    // E = Σ_{k=1..M} P(retries >= k) = Σ_{k=1..M} p^k
    let mut retries = 0.0;
    for k in 1..=policy.max_attempts {
        retries += p.powi(k as i32);
    }
    let extra_time_s = retries * dev.saturation.retry_penalty_s;
    // all max_attempts fail -> error; errors counted per request in batch
    let errors = p.powi(policy.max_attempts as i32) * batch_size as f64;
    FailureOutcome { retries, extra_time_s, errors }
}

/// Stochastic outcome sampled from the experiment RNG.
pub fn sample(
    dev: &DeviceProfile,
    saturation: f64,
    batch_size: usize,
    rng: &mut Rng,
) -> FailureOutcome {
    sample_with(dev, saturation, batch_size, rng, &FailurePolicy::default())
}

/// [`sample`] under an explicit policy.
pub fn sample_with(
    dev: &DeviceProfile,
    saturation: f64,
    batch_size: usize,
    rng: &mut Rng,
    policy: &FailurePolicy,
) -> FailureOutcome {
    let p = fail_prob_with(dev, saturation, policy);
    if p <= 0.0 {
        return FailureOutcome::CLEAN;
    }
    let mut retries = 0.0;
    let mut errors = 0.0;
    for _ in 0..policy.max_attempts {
        if !rng.chance(p) {
            return FailureOutcome {
                retries,
                extra_time_s: retries * dev.saturation.retry_penalty_s,
                errors,
            };
        }
        retries += 1.0;
    }
    // exhausted: the whole batch attempt chain failed; count batch errors
    errors += batch_size as f64;
    FailureOutcome {
        retries,
        extra_time_s: retries * dev.saturation.retry_penalty_s,
        errors,
    }
}

/// One scripted outage: `device` is Down over `[start_s, end_s)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OutageWindow {
    /// Device index (position in the cluster's device list).
    pub device: usize,
    /// Outage start, seconds since experiment start.
    pub start_s: f64,
    /// Outage end (the device comes back), seconds.
    pub end_s: f64,
}

impl OutageWindow {
    /// Parse a `"device:start_s:end_s"` spec, the form the
    /// `[serving.churn]` `outages` list and `--churn-outage` use.
    pub fn parse(spec: &str) -> Result<Self> {
        let parts: Vec<&str> = spec.split(':').collect();
        if parts.len() != 3 {
            bail!("outage spec '{spec}' must be device:start_s:end_s");
        }
        let device = parts[0]
            .trim()
            .parse::<usize>()
            .map_err(|_| anyhow!("outage spec '{spec}': bad device index '{}'", parts[0]))?;
        let start_s = parts[1]
            .trim()
            .parse::<f64>()
            .map_err(|_| anyhow!("outage spec '{spec}': bad start_s '{}'", parts[1]))?;
        let end_s = parts[2]
            .trim()
            .parse::<f64>()
            .map_err(|_| anyhow!("outage spec '{spec}': bad end_s '{}'", parts[2]))?;
        Ok(OutageWindow { device, start_s, end_s })
    }
}

/// A device-churn timeline: when each device is Down, and (via the
/// optional lead/tail intervals) when it is Degraded on the way into
/// an outage or Recovering on the way out.
///
/// The default schedule is empty — no churn, and every consumer's
/// churn-off path is bit-for-bit the pre-churn behaviour.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ChurnSchedule {
    /// Sorted by (start_s, device); per-device windows never overlap.
    windows: Vec<OutageWindow>,
    /// Devices report Degraded this long before each outage starts.
    degraded_lead_s: f64,
    /// Devices report Recovering this long after each outage ends.
    recovering_tail_s: f64,
}

fn severity_rank(s: HealthState) -> u8 {
    match s {
        HealthState::Up => 0,
        HealthState::Recovering => 1,
        HealthState::Degraded => 2,
        HealthState::Down => 3,
    }
}

impl ChurnSchedule {
    /// A deterministic schedule from explicit outage windows.
    /// Validates: finite, `start_s >= 0`, `end_s > start_s`, and no
    /// overlapping windows on the same device.
    pub fn scripted(mut windows: Vec<OutageWindow>) -> Result<Self> {
        for w in &windows {
            if !w.start_s.is_finite() || !w.end_s.is_finite() {
                bail!("outage window on device {} has non-finite bounds", w.device);
            }
            if w.start_s < 0.0 {
                bail!("outage window on device {} starts before t=0 ({})", w.device, w.start_s);
            }
            if w.end_s <= w.start_s {
                bail!(
                    "outage window on device {} is empty or reversed ({}..{})",
                    w.device,
                    w.start_s,
                    w.end_s
                );
            }
        }
        windows.sort_by(|a, b| {
            a.start_s
                .partial_cmp(&b.start_s)
                .expect("finite start_s")
                .then(a.device.cmp(&b.device))
        });
        let mut last_end: std::collections::BTreeMap<usize, f64> = Default::default();
        for w in &windows {
            if let Some(&end) = last_end.get(&w.device) {
                if w.start_s < end {
                    bail!(
                        "overlapping outage windows on device {} (second starts at {} before {} ends)",
                        w.device,
                        w.start_s,
                        end
                    );
                }
            }
            last_end.insert(w.device, w.end_s);
        }
        Ok(ChurnSchedule { windows, degraded_lead_s: 0.0, recovering_tail_s: 0.0 })
    }

    /// A stochastic schedule: per device, alternate exponential
    /// up-times (mean `mtbf_s`) and repair times (mean `mttr_s`),
    /// sampled from `rng`. New failures start before `horizon_s`;
    /// repairs may run past it.
    pub fn stochastic(
        n_devices: usize,
        mtbf_s: f64,
        mttr_s: f64,
        horizon_s: f64,
        rng: &mut Rng,
    ) -> Result<Self> {
        if n_devices == 0 {
            bail!("stochastic churn needs at least one device");
        }
        if !(mtbf_s > 0.0 && mtbf_s.is_finite()) {
            bail!("churn mtbf_s must be positive and finite, got {mtbf_s}");
        }
        if !(mttr_s > 0.0 && mttr_s.is_finite()) {
            bail!("churn mttr_s must be positive and finite, got {mttr_s}");
        }
        if !(horizon_s > 0.0 && horizon_s.is_finite()) {
            bail!("churn horizon_s must be positive and finite, got {horizon_s}");
        }
        let mut windows = Vec::new();
        for device in 0..n_devices {
            let mut t = rng.exponential(1.0 / mtbf_s);
            while t < horizon_s {
                let repair = rng.exponential(1.0 / mttr_s);
                windows.push(OutageWindow { device, start_s: t, end_s: t + repair });
                t += repair + rng.exponential(1.0 / mtbf_s);
            }
        }
        Self::scripted(windows)
    }

    /// Report Degraded for `lead_s` before each outage (must be >= 0).
    pub fn with_degraded_lead_s(mut self, lead_s: f64) -> Self {
        assert!(lead_s >= 0.0 && lead_s.is_finite(), "degraded lead must be >= 0");
        self.degraded_lead_s = lead_s;
        self
    }

    /// Report Recovering for `tail_s` after each outage (must be >= 0).
    pub fn with_recovering_tail_s(mut self, tail_s: f64) -> Self {
        assert!(tail_s >= 0.0 && tail_s.is_finite(), "recovering tail must be >= 0");
        self.recovering_tail_s = tail_s;
        self
    }

    /// True when the schedule contains no outages (churn off).
    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    /// The validated, sorted outage windows.
    pub fn windows(&self) -> &[OutageWindow] {
        &self.windows
    }

    /// Largest device index any window references.
    pub fn max_device(&self) -> Option<usize> {
        self.windows.iter().map(|w| w.device).max()
    }

    /// The device's health state at time `t`. Down inside a window;
    /// Degraded in the lead interval before one (taking precedence
    /// over Recovering); Recovering in the tail after one; Up
    /// otherwise.
    pub fn state_at(&self, device: usize, t: f64) -> HealthState {
        let mut s = HealthState::Up;
        for w in self.windows.iter().filter(|w| w.device == device) {
            if t >= w.start_s && t < w.end_s {
                return HealthState::Down;
            }
            if self.recovering_tail_s > 0.0
                && t >= w.end_s
                && t < w.end_s + self.recovering_tail_s
                && s == HealthState::Up
            {
                s = HealthState::Recovering;
            }
            if self.degraded_lead_s > 0.0 && t >= w.start_s - self.degraded_lead_s && t < w.start_s
            {
                s = HealthState::Degraded;
            }
        }
        s
    }

    /// If `device` is Down at `t`, the instant it comes back up.
    pub fn down_until(&self, device: usize, t: f64) -> Option<f64> {
        self.windows
            .iter()
            .find(|w| w.device == device && t >= w.start_s && t < w.end_s)
            .map(|w| w.end_s)
    }

    /// Every state change as `(time, device, new_state)`, sorted by
    /// time (ties: device index, then mildest state first so applying
    /// in order leaves the most severe state standing). Applying the
    /// prefix up to `t` reproduces [`ChurnSchedule::state_at`].
    pub fn transitions(&self) -> Vec<(f64, usize, HealthState)> {
        let mut out = Vec::new();
        for w in &self.windows {
            if self.degraded_lead_s > 0.0 {
                out.push((
                    (w.start_s - self.degraded_lead_s).max(0.0),
                    w.device,
                    HealthState::Degraded,
                ));
            }
            out.push((w.start_s, w.device, HealthState::Down));
            if self.recovering_tail_s > 0.0 {
                out.push((w.end_s, w.device, HealthState::Recovering));
                out.push((w.end_s + self.recovering_tail_s, w.device, HealthState::Up));
            } else {
                out.push((w.end_s, w.device, HealthState::Up));
            }
        }
        out.sort_by(|a, b| {
            a.0.partial_cmp(&b.0)
                .expect("finite transition times")
                .then(a.1.cmp(&b.1))
                .then(severity_rank(a.2).cmp(&severity_rank(b.2)))
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::DeviceProfile;
    use crate::util::check::property;

    #[test]
    fn zero_saturation_is_clean() {
        let j = DeviceProfile::jetson();
        assert_eq!(expected(&j, 0.0, 8), FailureOutcome::CLEAN);
        let mut rng = Rng::new(1);
        assert_eq!(sample(&j, 0.0, 8, &mut rng), FailureOutcome::CLEAN);
    }

    #[test]
    fn expected_monotone_in_saturation() {
        let j = DeviceProfile::jetson();
        let low = expected(&j, 0.2, 8);
        let high = expected(&j, 1.5, 8);
        assert!(high.retries > low.retries);
        assert!(high.extra_time_s > low.extra_time_s);
        assert!(high.errors > low.errors);
    }

    #[test]
    fn jetson_more_fragile_than_ada() {
        let j = DeviceProfile::jetson();
        let a = DeviceProfile::ada();
        assert!(fail_prob(&j, 1.0) > fail_prob(&a, 1.0));
    }

    #[test]
    fn prob_clamped() {
        let j = DeviceProfile::jetson();
        assert!(fail_prob(&j, 1e9) <= MAX_FAIL_PROB);
    }

    #[test]
    fn default_policy_matches_hardcoded_constants_bitwise() {
        let p = FailurePolicy::default();
        assert_eq!(p.max_attempts, MAX_ATTEMPTS);
        assert_eq!(p.max_fail_prob.to_bits(), MAX_FAIL_PROB.to_bits());
        let j = DeviceProfile::jetson();
        for sat in [0.0, 0.2, 1.0, 1.7] {
            let a = expected(&j, sat, 8);
            let b = expected_with(&j, sat, 8, &p);
            assert_eq!(a.retries.to_bits(), b.retries.to_bits());
            assert_eq!(a.extra_time_s.to_bits(), b.extra_time_s.to_bits());
            assert_eq!(a.errors.to_bits(), b.errors.to_bits());
            let mut r1 = Rng::new(7);
            let mut r2 = Rng::new(7);
            assert_eq!(sample(&j, sat, 8, &mut r1), sample_with(&j, sat, 8, &mut r2, &p));
        }
    }

    #[test]
    fn custom_policy_changes_the_chain() {
        let j = DeviceProfile::jetson();
        let sat = 1.5;
        let strict = FailurePolicy { max_attempts: 1, max_fail_prob: 0.9 };
        let lax = FailurePolicy { max_attempts: 6, max_fail_prob: 0.9 };
        let e1 = expected_with(&j, sat, 8, &strict);
        let e6 = expected_with(&j, sat, 8, &lax);
        // fewer attempts -> more exhausted chains (errors), fewer retries
        assert!(e1.errors > e6.errors);
        assert!(e1.retries < e6.retries);
        let capped = FailurePolicy { max_attempts: 3, max_fail_prob: 0.1 };
        assert!(fail_prob_with(&j, 1e9, &capped) <= 0.1);
    }

    #[test]
    fn policy_validation_rejects_bad_values() {
        assert!(FailurePolicy::default().validate().is_ok());
        assert!(FailurePolicy { max_attempts: 0, max_fail_prob: 0.5 }.validate().is_err());
        assert!(FailurePolicy { max_attempts: 3, max_fail_prob: 1.0 }.validate().is_err());
        assert!(FailurePolicy { max_attempts: 3, max_fail_prob: -0.1 }.validate().is_err());
        assert!(FailurePolicy { max_attempts: 3, max_fail_prob: f64::NAN }.validate().is_err());
    }

    #[test]
    fn sampled_mean_matches_expected() {
        let j = DeviceProfile::jetson();
        let sat = 1.0;
        let exp = expected(&j, sat, 4);
        let mut rng = Rng::new(99);
        let n = 20_000;
        let mut retries = 0.0;
        let mut errors = 0.0;
        for _ in 0..n {
            let o = sample(&j, sat, 4, &mut rng);
            retries += o.retries;
            errors += o.errors;
        }
        let mean_retries = retries / n as f64;
        let mean_errors = errors / n as f64;
        assert!(
            (mean_retries - exp.retries).abs() / exp.retries.max(1e-9) < 0.05,
            "retries {mean_retries} vs {}",
            exp.retries
        );
        assert!(
            (mean_errors - exp.errors).abs() / exp.errors.max(1e-9) < 0.15,
            "errors {mean_errors} vs {}",
            exp.errors
        );
    }

    #[test]
    fn outcomes_always_nonnegative() {
        property("failure outcomes nonnegative", 128, |rng| {
            let dev = if rng.chance(0.5) { DeviceProfile::jetson() } else { DeviceProfile::ada() };
            let sat = rng.range(0.0, 3.0);
            let b = rng.below(8) + 1;
            let o = sample(&dev, sat, b, rng);
            if o.retries >= 0.0 && o.extra_time_s >= 0.0 && o.errors >= 0.0 {
                Ok(())
            } else {
                Err(format!("{o:?}"))
            }
        });
    }

    fn w(device: usize, start_s: f64, end_s: f64) -> OutageWindow {
        OutageWindow { device, start_s, end_s }
    }

    #[test]
    fn scripted_schedule_validates_windows() {
        assert!(ChurnSchedule::scripted(vec![]).unwrap().is_empty());
        assert!(ChurnSchedule::scripted(vec![w(0, 10.0, 20.0), w(1, 5.0, 8.0)]).is_ok());
        // reversed / empty / negative / non-finite / overlapping all fail
        assert!(ChurnSchedule::scripted(vec![w(0, 20.0, 10.0)]).is_err());
        assert!(ChurnSchedule::scripted(vec![w(0, 10.0, 10.0)]).is_err());
        assert!(ChurnSchedule::scripted(vec![w(0, -1.0, 10.0)]).is_err());
        assert!(ChurnSchedule::scripted(vec![w(0, 0.0, f64::INFINITY)]).is_err());
        assert!(ChurnSchedule::scripted(vec![w(0, 0.0, 10.0), w(0, 5.0, 15.0)]).is_err());
        // back-to-back on one device and overlap across devices are fine
        assert!(ChurnSchedule::scripted(vec![w(0, 0.0, 10.0), w(0, 10.0, 15.0)]).is_ok());
        assert!(ChurnSchedule::scripted(vec![w(0, 0.0, 10.0), w(1, 5.0, 15.0)]).is_ok());
    }

    #[test]
    fn state_at_walks_the_full_cycle() {
        let sched = ChurnSchedule::scripted(vec![w(1, 100.0, 200.0)])
            .unwrap()
            .with_degraded_lead_s(30.0)
            .with_recovering_tail_s(50.0);
        assert_eq!(sched.state_at(1, 0.0), HealthState::Up);
        assert_eq!(sched.state_at(1, 80.0), HealthState::Degraded);
        assert_eq!(sched.state_at(1, 100.0), HealthState::Down);
        assert_eq!(sched.state_at(1, 199.9), HealthState::Down);
        assert_eq!(sched.state_at(1, 200.0), HealthState::Recovering);
        assert_eq!(sched.state_at(1, 260.0), HealthState::Up);
        // other devices unaffected
        assert_eq!(sched.state_at(0, 150.0), HealthState::Up);
        assert_eq!(sched.down_until(1, 150.0), Some(200.0));
        assert_eq!(sched.down_until(1, 250.0), None);
        assert_eq!(sched.max_device(), Some(1));
    }

    #[test]
    fn transitions_replay_state_at() {
        let sched = ChurnSchedule::scripted(vec![w(0, 50.0, 80.0), w(1, 60.0, 90.0)])
            .unwrap()
            .with_degraded_lead_s(10.0)
            .with_recovering_tail_s(5.0);
        let trans = sched.transitions();
        // sorted by time
        for pair in trans.windows(2) {
            assert!(pair[0].0 <= pair[1].0, "unsorted transitions");
        }
        // applying the prefix reproduces state_at just after each
        // change (checked once all same-timestamp transitions applied)
        let mut mask = [HealthState::Up; 2];
        for (i, &(t, d, s)) in trans.iter().enumerate() {
            mask[d] = s;
            if trans.get(i + 1).is_some_and(|next| next.0 <= t) {
                continue;
            }
            for dev in 0..2 {
                assert_eq!(
                    mask[dev],
                    sched.state_at(dev, t + 1e-9),
                    "divergence at t={t} dev={dev}"
                );
            }
        }
        // after the last transition everyone is Up again
        assert!(mask.iter().all(|s| *s == HealthState::Up));
    }

    #[test]
    fn stochastic_schedule_is_deterministic_and_valid() {
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        let a = ChurnSchedule::stochastic(3, 3600.0, 300.0, 86_400.0, &mut r1).unwrap();
        let b = ChurnSchedule::stochastic(3, 3600.0, 300.0, 86_400.0, &mut r2).unwrap();
        assert_eq!(a, b);
        assert!(!a.is_empty(), "a day at 1h MTBF should fail sometime");
        // scripted() re-validated it: per-device windows are disjoint
        // and sorted; every start is within the horizon
        for win in a.windows() {
            assert!(win.start_s < 86_400.0);
            assert!(win.end_s > win.start_s);
        }
        assert!(ChurnSchedule::stochastic(0, 1.0, 1.0, 1.0, &mut Rng::new(1)).is_err());
        assert!(ChurnSchedule::stochastic(1, 0.0, 1.0, 1.0, &mut Rng::new(1)).is_err());
        assert!(ChurnSchedule::stochastic(1, 1.0, -1.0, 1.0, &mut Rng::new(1)).is_err());
        assert!(ChurnSchedule::stochastic(1, 1.0, 1.0, 0.0, &mut Rng::new(1)).is_err());
    }

    #[test]
    fn outage_spec_parses() {
        let win = OutageWindow::parse("1:600:1800").unwrap();
        assert_eq!(win, w(1, 600.0, 1800.0));
        let win = OutageWindow::parse(" 0 : 0.5 : 9.25 ").unwrap();
        assert_eq!(win, w(0, 0.5, 9.25));
        assert!(OutageWindow::parse("1:600").is_err());
        assert!(OutageWindow::parse("x:600:1800").is_err());
        assert!(OutageWindow::parse("1:abc:1800").is_err());
        assert!(OutageWindow::parse("1:600:def").is_err());
    }
}
