//! Failure injection: memory-saturation instability (paper §3).
//!
//! The paper observes that batch 8 on the 8 GB Jetson "introduces
//! instability and accuracy degradation ... errors due to memory
//! saturation". We model it as an OOM/retry process driven by the
//! memory model's saturation overshoot:
//!
//! - with probability `failure_prob_per_sat × saturation` an attempt
//!   fails (clamped at 0.9);
//! - each failed attempt costs `retry_penalty_s` wallclock (and the
//!   corresponding active energy) before the retry;
//! - a request that fails `MAX_ATTEMPTS` times is recorded as an error
//!   (the paper's "accuracy degradation" shows up as our error rate).
//!
//! Two evaluation modes:
//! - [`expected`] — deterministic expected-value penalties (used by the
//!   table benches so rows replay exactly);
//! - [`sample`] — stochastic injection from the experiment RNG (used by
//!   failure-injection tests and the serving loop).

use crate::cluster::DeviceProfile;
use crate::util::rng::Rng;

/// Retries after which the request is declared failed.
pub const MAX_ATTEMPTS: usize = 3;
/// Hard cap on per-attempt failure probability.
pub const MAX_FAIL_PROB: f64 = 0.9;

/// Result of failure evaluation for one batch attempt chain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureOutcome {
    /// Number of failed attempts before success (0 = clean).
    pub retries: f64,
    /// Extra wallclock spent on failed attempts, seconds.
    pub extra_time_s: f64,
    /// Probability-weighted count of requests that exhausted retries
    /// (deterministic mode) or 0/1 (sampled mode), per batch.
    pub errors: f64,
}

impl FailureOutcome {
    pub const CLEAN: FailureOutcome =
        FailureOutcome { retries: 0.0, extra_time_s: 0.0, errors: 0.0 };
}

/// Per-attempt failure probability for a device at a saturation level.
pub fn fail_prob(dev: &DeviceProfile, saturation: f64) -> f64 {
    (dev.saturation.failure_prob_per_sat * saturation).clamp(0.0, MAX_FAIL_PROB)
}

/// Deterministic expected-value outcome (geometric retry chain).
pub fn expected(dev: &DeviceProfile, saturation: f64, batch_size: usize) -> FailureOutcome {
    let p = fail_prob(dev, saturation);
    if p <= 0.0 {
        return FailureOutcome::CLEAN;
    }
    // expected failed attempts, capped at MAX_ATTEMPTS:
    // E = Σ_{k=1..M} P(retries >= k) = Σ_{k=1..M} p^k
    let mut retries = 0.0;
    for k in 1..=MAX_ATTEMPTS {
        retries += p.powi(k as i32);
    }
    let extra_time_s = retries * dev.saturation.retry_penalty_s;
    // all MAX_ATTEMPTS fail -> error; errors counted per request in batch
    let errors = p.powi(MAX_ATTEMPTS as i32) * batch_size as f64;
    FailureOutcome { retries, extra_time_s, errors }
}

/// Stochastic outcome sampled from the experiment RNG.
pub fn sample(dev: &DeviceProfile, saturation: f64, batch_size: usize, rng: &mut Rng) -> FailureOutcome {
    let p = fail_prob(dev, saturation);
    if p <= 0.0 {
        return FailureOutcome::CLEAN;
    }
    let mut retries = 0.0;
    let mut errors = 0.0;
    for _ in 0..MAX_ATTEMPTS {
        if !rng.chance(p) {
            return FailureOutcome {
                retries,
                extra_time_s: retries * dev.saturation.retry_penalty_s,
                errors,
            };
        }
        retries += 1.0;
    }
    // exhausted: the whole batch attempt chain failed; count batch errors
    errors += batch_size as f64;
    FailureOutcome {
        retries,
        extra_time_s: retries * dev.saturation.retry_penalty_s,
        errors,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::DeviceProfile;
    use crate::util::check::property;

    #[test]
    fn zero_saturation_is_clean() {
        let j = DeviceProfile::jetson();
        assert_eq!(expected(&j, 0.0, 8), FailureOutcome::CLEAN);
        let mut rng = Rng::new(1);
        assert_eq!(sample(&j, 0.0, 8, &mut rng), FailureOutcome::CLEAN);
    }

    #[test]
    fn expected_monotone_in_saturation() {
        let j = DeviceProfile::jetson();
        let low = expected(&j, 0.2, 8);
        let high = expected(&j, 1.5, 8);
        assert!(high.retries > low.retries);
        assert!(high.extra_time_s > low.extra_time_s);
        assert!(high.errors > low.errors);
    }

    #[test]
    fn jetson_more_fragile_than_ada() {
        let j = DeviceProfile::jetson();
        let a = DeviceProfile::ada();
        assert!(fail_prob(&j, 1.0) > fail_prob(&a, 1.0));
    }

    #[test]
    fn prob_clamped() {
        let j = DeviceProfile::jetson();
        assert!(fail_prob(&j, 1e9) <= MAX_FAIL_PROB);
    }

    #[test]
    fn sampled_mean_matches_expected() {
        let j = DeviceProfile::jetson();
        let sat = 1.0;
        let exp = expected(&j, sat, 4);
        let mut rng = Rng::new(99);
        let n = 20_000;
        let mut retries = 0.0;
        let mut errors = 0.0;
        for _ in 0..n {
            let o = sample(&j, sat, 4, &mut rng);
            retries += o.retries;
            errors += o.errors;
        }
        let mean_retries = retries / n as f64;
        let mean_errors = errors / n as f64;
        assert!(
            (mean_retries - exp.retries).abs() / exp.retries.max(1e-9) < 0.05,
            "retries {mean_retries} vs {}",
            exp.retries
        );
        assert!(
            (mean_errors - exp.errors).abs() / exp.errors.max(1e-9) < 0.15,
            "errors {mean_errors} vs {}",
            exp.errors
        );
    }

    #[test]
    fn outcomes_always_nonnegative() {
        property("failure outcomes nonnegative", 128, |rng| {
            let dev = if rng.chance(0.5) { DeviceProfile::jetson() } else { DeviceProfile::ada() };
            let sat = rng.range(0.0, 3.0);
            let b = rng.below(8) + 1;
            let o = sample(&dev, sat, b, rng);
            if o.retries >= 0.0 && o.extra_time_s >= 0.0 && o.errors >= 0.0 {
                Ok(())
            } else {
                Err(format!("{o:?}"))
            }
        });
    }
}
