//! Bench: regenerate the paper's Fig. 1 and Fig. 2 series (plus the
//! cross-batch sweep and ablations) and time them.
//! Run with `cargo bench --bench figures`.

use verdant::bench::{ablation, fig1, fig2, harness, sweep, Env};

fn main() {
    harness::group("Fig. 1 / Fig. 2 — canonical prompt experiments");

    let r = harness::bench("fig1/P1-P4 x 3 backends", 2, 20, fig1::run);
    harness::report(&r);
    let r = harness::bench("fig2/P1-P4 x 2 models", 2, 20, fig2::run);
    harness::report(&r);

    let env = Env::standard();
    let r = harness::bench("sweep/3-strategies x 5 batches", 1, 3, || sweep::run(&env));
    harness::report(&r);
    let r = harness::bench("ablation/3-studies", 1, 3, || ablation::run(&env));
    harness::report(&r);

    for table in [fig1::run().1, fig2::run().1, sweep::run(&env).1, ablation::run(&env).1] {
        println!("\n{}", table.ascii());
        let name = table.name.clone();
        let _ = table.save(std::path::Path::new("results"));
        println!("saved results/{name}.{{csv,json}}");
    }
}
