//! Bench: regenerate the paper's Table 3 (strategy comparison) and time
//! the full route→batch→execute→account pipeline per strategy.
//! Run with `cargo bench --bench table3`.

use verdant::bench::{harness, table3, Env};
use verdant::config::ExecutionMode;
use verdant::coordinator::{run, Grouping, PlacementPolicy, RunConfig};

fn main() {
    harness::group("Table 3 — strategy comparison across batch sizes");

    let env = Env::standard();

    // per-strategy end-to-end pipeline cost at batch 4 (the hot path a
    // deployment would re-run whenever the corpus changes)
    for name in table3::PAPER_STRATEGIES {
        let strategy = PlacementPolicy::spatial(name, &env.cluster).unwrap();
        let cfg = RunConfig {
            batch_size: 4,
            grouping: Grouping::Fifo,
            execution: ExecutionMode::Calibrated,
            max_new_tokens: 96,
            stochastic_seed: None,
            continuous_batching: false,
            ..RunConfig::default()
        };
        let r = harness::bench(&format!("table3/run/{name}"), 1, 10, || {
            run(&env.cluster, &env.prompts, &strategy, &env.db, &cfg, None).unwrap()
        });
        harness::report(&r);
    }

    // the whole table (12 paper rows + 9 extension rows)
    let r = harness::bench("table3/full-table+extensions", 1, 3, || table3::run(&env, true));
    harness::report(&r);

    let (_, table) = table3::run(&env, true);
    println!("\n{}", table.ascii());
    let _ = table.save(std::path::Path::new("results"));
    println!("saved results/table3.{{csv,json}}");
}
