//! Bench: regenerate the paper's Table 2 (per-device, per-batch averages)
//! and time the pipeline. Run with `cargo bench --bench table2`.

use verdant::bench::{harness, table2, Env};

fn main() {
    harness::group("Table 2 — average inference metrics per (device, batch)");

    // full paper-scale corpus
    let env = Env::standard();
    let r = harness::bench("table2/500-prompts/6-configs", 1, 5, || table2::run(&env));
    harness::report(&r);

    // scaling in corpus size
    for n in [100usize, 1000] {
        let env_n = Env::small(n);
        let r = harness::bench(&format!("table2/{n}-prompts"), 1, 3, || table2::run(&env_n));
        harness::report(&r);
    }

    // emit the actual table (the artefact this bench regenerates)
    let (_, table) = table2::run(&env);
    println!("\n{}", table.ascii());
    let _ = table.save(std::path::Path::new("results"));
    println!("saved results/table2.{{csv,json}}");
}
