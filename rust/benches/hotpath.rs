//! Bench: L3 coordinator hot paths in isolation (§Perf targets).
//!
//! The serving-relevant inner loops: routing 500 prompts, batch
//! formation, one simulated batch, estimator lookups, benchmark-DB
//! construction, the DES queue, and real PJRT decode steps when
//! artifacts are present. Run with `cargo bench --bench hotpath`.

use verdant::bench::{harness, Env};
use verdant::cluster::CarbonModel;
use verdant::coordinator::{
    build_strategy, estimator, form_batches, GridShiftConfig, Grouping, OnlineView, RouteContext,
    Strategy,
};
use verdant::grid::ForecastKind;
use verdant::runtime::{CalibratedBackend, InferenceBackend};
use verdant::simulator::{simulate_batch, BatchWork, EventQueue};

fn main() {
    harness::group("L3 hot paths");

    let env = Env::standard();
    let ctx = RouteContext { cluster: &env.cluster, db: &env.db, batch_size: 4 };

    for name in ["carbon-aware", "latency-aware", "round-robin"] {
        let s = build_strategy(name, &env.cluster).unwrap();
        let r = harness::bench(&format!("route/500/{name}"), 3, 50, || {
            s.assign(&env.prompts, &ctx)
        });
        harness::report(&r);
    }

    let s = build_strategy("latency-aware", &env.cluster).unwrap();
    let assignment = s.assign(&env.prompts, &ctx);
    let r = harness::bench("batcher/500-prompts", 3, 100, || {
        form_batches(&env.prompts, &assignment, 4, &env.cluster, Grouping::Fifo)
    });
    harness::report(&r);

    let jetson = &env.cluster.devices[0];
    let work = BatchWork::new(vec![150; 8], vec![148; 8]);
    let r = harness::bench("simulate_batch/b8", 10, 10_000, || {
        simulate_batch(jetson, &work, None)
    });
    harness::report(&r);

    let p = &env.prompts[0];
    let r = harness::bench("estimator/analytic", 10, 10_000, || {
        estimator::estimate(jetson, p, 4, 69.0)
    });
    harness::report(&r);
    let r = harness::bench("estimator/db-lookup", 10, 100_000, || {
        env.db.cost(jetson, p, 4)
    });
    harness::report(&r);

    // forecast-priced on-arrival routing: per-step memo vs refitting
    // the forecaster on every decision (the pre-cache hot path)
    let trace = CarbonModel::diurnal(69.0, 0.3).to_trace(900.0);
    let grid_memo = GridShiftConfig::new(trace.clone(), ForecastKind::Harmonic);
    let grid_refit = GridShiftConfig::new(trace, ForecastKind::Harmonic).with_memoize(false);
    let fca = build_strategy("forecast-carbon-aware", &env.cluster).unwrap();
    let backlog = vec![120.0; env.cluster.devices.len()];
    for (label, grid) in [("memoized", &grid_memo), ("refit", &grid_refit)] {
        let r = harness::bench(&format!("route-one/forecast/{label}"), 3, 2_000, || {
            let view = OnlineView { backlog_s: &backlog, now: 17.0 * 3600.0, grid: Some(grid) };
            Strategy::route_one(fca.as_ref(), p, &ctx, &view)
        });
        harness::report(&r);
    }

    let r = harness::bench("benchmark-db/build/6-per-cell", 1, 5, || {
        estimator::BenchmarkDb::build(&env.cluster, &[1, 4, 8], 6, 69.0, 1)
    });
    harness::report(&r);

    // the stub backend the wallclock plane batches through in `bench
    // scale` / CI: its per-batch synthesis cost must stay negligible
    // next to the scheduling work it unblocks
    let stub = CalibratedBackend::from_cluster(&env.cluster);
    let stub_prompts: Vec<&str> = env.prompts[..4].iter().map(|p| p.text.as_str()).collect();
    let r = harness::bench("backend/stub/generate-b4", 5, 5_000, || {
        stub.generate("edge-1b-sim", 4, &stub_prompts, 16).unwrap()
    });
    harness::report(&r);
    let r = harness::bench("backend/stub/pick-batch", 10, 100_000, || {
        stub.pick_batch("edge-1b-sim", 3)
    });
    harness::report(&r);

    let r = harness::bench("event-queue/push+pop 10k", 3, 200, || {
        let mut q = EventQueue::new();
        for i in 0..10_000u32 {
            q.push((i % 97) as f64, i);
        }
        let mut acc = 0u64;
        while let Some(e) = q.pop() {
            acc = acc.wrapping_add(e.event as u64);
        }
        acc
    });
    harness::report(&r);

    // --- real PJRT decode hot path (needs artifacts) -------------------
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("manifest.json").exists() {
        harness::group("PJRT request path (edge-1b-sim)");
        // through the backend trait, exactly as the planes now call it
        // — any dispatch overhead shows up against the old direct rows
        let pjrt = verdant::runtime::PjrtBackend::load(&artifacts, &["edge-1b-sim"]).unwrap();

        let prompts_b1 = ["Who painted the Mona Lisa?"];
        let r = harness::bench("backend/pjrt/generate/b1/8-new-tokens", 2, 20, || {
            pjrt.generate("edge-1b-sim", 1, &prompts_b1, 8).unwrap()
        });
        harness::report(&r);

        let r = harness::bench("backend/pjrt/generate/b1/32-new-tokens", 2, 10, || {
            pjrt.generate("edge-1b-sim", 1, &prompts_b1, 32).unwrap()
        });
        harness::report(&r);

        let owned_b4: Vec<String> =
            (0..4).map(|i| format!("Edge prompt number {i} with some body text")).collect();
        let prompts_b4: Vec<&str> = owned_b4.iter().map(String::as_str).collect();
        let r = harness::bench("backend/pjrt/generate/b4/8-new-tokens", 2, 10, || {
            pjrt.generate("edge-1b-sim", 4, &prompts_b4, 8).unwrap()
        });
        harness::report(&r);

        let r = harness::bench("pjrt/generate/b1/8-new-tokens (direct session)", 2, 20, || {
            verdant::runtime::generate(pjrt.engine(), "edge-1b-sim", 1, &prompts_b1, 8).unwrap()
        });
        harness::report(&r);
    } else {
        println!("(skipping PJRT benches: run `make artifacts` first)");
    }
}
