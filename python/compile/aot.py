"""AOT compile path: lower the L2 model to HLO text + weight sidecars.

Run once by ``make artifacts``; Python never appears on the request path.
For every model variant and every paper batch size b in {1, 4, 8} this
emits:

  artifacts/<variant>/prefill_b<b>.hlo.txt
  artifacts/<variant>/decode_b<b>.hlo.txt
  artifacts/<variant>.weights.bin       (flat little-endian tensor dump)
  artifacts/manifest.json               (geometry + param layout + entries)

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the `xla`
crate's xla_extension 0.5.1 rejects (proto.id() <= INT_MAX); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md). Lowered with return_tuple=True; the Rust
side unwraps with decompose_tuple().

Weights are passed as runtime *parameters* (leading arguments, in
cfg.param_layout() order) rather than baked constants: the sidecar binary
is loaded once by rust/src/runtime/engine.rs and kept as PJRT literals.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib

import numpy as np
import jax
from jax._src.lib import xla_client as xc

from . import configs, model

DTYPE_NP = {"f32": np.float32, "i8": np.int8}


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _param_specs(cfg: configs.ModelConfig) -> list[jax.ShapeDtypeStruct]:
    return [
        jax.ShapeDtypeStruct(shape, DTYPE_NP[dt])
        for (_, dt, shape) in cfg.param_layout()
    ]


def _make_prefill_fn(cfg: configs.ModelConfig, n_params: int):
    def f(*args):
        params = list(args[:n_params])
        tokens, lens = args[n_params], args[n_params + 1]
        return model.prefill(cfg, params, tokens, lens)

    return f


def _make_decode_fn(cfg: configs.ModelConfig, n_params: int):
    def f(*args):
        params = list(args[:n_params])
        token, pos, kv_k, kv_v = args[n_params : n_params + 4]
        return model.decode_step(cfg, params, token, pos, kv_k, kv_v)

    return f


def _make_decode_chunk_fn(cfg: configs.ModelConfig, n_params: int, steps: int):
    def f(*args):
        params = list(args[:n_params])
        token, pos, kv_k, kv_v = args[n_params : n_params + 4]
        return model.decode_chunk(cfg, params, token, pos, kv_k, kv_v, steps)

    return f


def lower_variant(cfg: configs.ModelConfig, out_dir: pathlib.Path,
                  batch_sizes=configs.BATCH_SIZES,
                  prefill_len: int = configs.PREFILL_LEN) -> dict:
    """Lower all (entry, batch) artifacts for one variant; return manifest."""
    if cfg.max_seq < prefill_len:
        raise ValueError(
            f"{cfg.name}: max_seq={cfg.max_seq} < prefill_len={prefill_len}"
        )
    layout = cfg.param_layout()
    n = len(layout)
    pspecs = _param_specs(cfg)
    vdir = out_dir / cfg.name
    vdir.mkdir(parents=True, exist_ok=True)

    kv_shape = (cfg.n_layers, None, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim)
    entries = {}
    for b in batch_sizes:
        kv = jax.ShapeDtypeStruct(
            tuple(b if d is None else d for d in kv_shape), np.float32
        )
        # prefill(params..., tokens[b, S], lens[b])
        pf = jax.jit(_make_prefill_fn(cfg, n)).lower(
            *pspecs,
            jax.ShapeDtypeStruct((b, prefill_len), np.int32),
            jax.ShapeDtypeStruct((b,), np.int32),
        )
        path = vdir / f"prefill_b{b}.hlo.txt"
        path.write_text(to_hlo_text(pf))
        entries[f"prefill_b{b}"] = {
            "file": f"{cfg.name}/prefill_b{b}.hlo.txt",
            "kind": "prefill",
            "batch": b,
            "prefill_len": prefill_len,
        }
        # decode(params..., token[b], pos[b], kv_k, kv_v)
        dc = jax.jit(_make_decode_fn(cfg, n)).lower(
            *pspecs,
            jax.ShapeDtypeStruct((b,), np.int32),
            jax.ShapeDtypeStruct((b,), np.int32),
            kv,
            kv,
        )
        path = vdir / f"decode_b{b}.hlo.txt"
        path.write_text(to_hlo_text(dc))
        entries[f"decode_b{b}"] = {
            "file": f"{cfg.name}/decode_b{b}.hlo.txt",
            "kind": "decode",
            "batch": b,
        }
        # chunked decode (§Perf): DECODE_CHUNK greedy steps per launch
        dck = jax.jit(_make_decode_chunk_fn(cfg, n, configs.DECODE_CHUNK)).lower(
            *pspecs,
            jax.ShapeDtypeStruct((b,), np.int32),
            jax.ShapeDtypeStruct((b,), np.int32),
            kv,
            kv,
        )
        path = vdir / f"decode_chunk_b{b}.hlo.txt"
        path.write_text(to_hlo_text(dck))
        entries[f"decode_chunk_b{b}"] = {
            "file": f"{cfg.name}/decode_chunk_b{b}.hlo.txt",
            "kind": "decode_chunk",
            "batch": b,
            "steps": configs.DECODE_CHUNK,
        }

    # Weight sidecar: flat little-endian dump in layout order.
    params = model.init_params(cfg)
    weights_file = f"{cfg.name}.weights.bin"
    pmeta = []
    offset = 0
    with open(out_dir / weights_file, "wb") as f:
        for (name, dt, shape), arr in zip(layout, params):
            assert arr.dtype == DTYPE_NP[dt] and arr.shape == tuple(shape), name
            raw = np.ascontiguousarray(arr).tobytes()
            f.write(raw)
            pmeta.append({
                "name": name, "dtype": dt, "shape": list(shape),
                "offset": offset, "bytes": len(raw),
            })
            offset += len(raw)

    return {
        "weights_file": weights_file,
        "weights_bytes": offset,
        "weights_sha256": hashlib.sha256(
            (out_dir / weights_file).read_bytes()
        ).hexdigest(),
        "params": pmeta,
        "config": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads, "head_dim": cfg.head_dim,
            "d_ff": cfg.d_ff, "max_seq": cfg.max_seq,
            "rope_theta": cfg.rope_theta, "seed": cfg.seed,
        },
        "entries": entries,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--variants", nargs="*", default=list(configs.VARIANTS),
                    help="subset of variants to lower")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out).resolve()
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest = {
        "version": configs.MANIFEST_VERSION,
        "prefill_len": configs.PREFILL_LEN,
        "max_seq": configs.MAX_SEQ,
        "vocab": configs.VOCAB,
        "eos_id": configs.EOS_ID,
        "batch_sizes": list(configs.BATCH_SIZES),
        "variants": {},
    }
    for name in args.variants:
        cfg = configs.VARIANTS[name]
        print(f"[aot] lowering {name} ...", flush=True)
        manifest["variants"][name] = lower_variant(cfg, out_dir)

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    total = sum(
        (out_dir / e["file"]).stat().st_size
        for v in manifest["variants"].values()
        for e in v["entries"].values()
    )
    print(f"[aot] wrote manifest + {total/1e6:.1f} MB of HLO under {out_dir}")


if __name__ == "__main__":
    main()
