"""L2: Gemma-style decoder-only transformer in JAX, calling the L1 kernels.

Two entry points are AOT-lowered per (variant, batch) by aot.py:

  prefill(params, tokens[B,S], lens[B])
      -> (last_logits[B,V], kv_k[L,B,Smax,Hkv,Dh], kv_v[L,B,Smax,Hkv,Dh])

  decode_step(params, token[B], pos[B], kv_k, kv_v)
      -> (logits[B,V], kv_k', kv_v')

Conventions (the Rust runtime mirrors all of these):
  - prompts are right-padded to S = PREFILL_LEN; lens[b] gives the true
    prompt length; prefill returns the logits at position lens[b]-1;
  - the KV cache is allocated at Smax = cfg.max_seq and threaded through
    decode steps as whole arrays (rust passes the previous step's outputs
    back in as inputs);
  - decode writes k/v at index pos[b] per row and attends over
    [0, pos[b]] inclusive via the flash-decode Pallas kernel;
  - weights arrive as a flat list in cfg.param_layout() order (int8 MLP
    weights + f32 scales — the paper's QAT quantization — and f32
    attention/embedding weights).

The hot compute runs through the Pallas kernels: quant_matmul for every
MLP projection, rmsnorm for every norm, decode_attention for the decode
hot path. Prefill attention is plain jnp (one-shot, not the serving hot
path; XLA fuses it fine — see DESIGN.md §Perf L2 audit).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.decode_attention import decode_attention
from .kernels.quant_matmul import quant_matmul, quantize_per_channel
from .kernels.rmsnorm import rmsnorm


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def init_params(cfg: ModelConfig) -> list[np.ndarray]:
    """Deterministic seeded weights in cfg.param_layout() order.

    f32 tensors are N(0, 1/sqrt(fan_in)); i8 tensors are produced by
    symmetric per-channel quantization of such a draw (scales follow in
    the layout). Norm gains start at 0 (Gemma's (1+w) convention).
    """
    rng = np.random.default_rng(cfg.seed)
    layout = cfg.param_layout()
    params: list[np.ndarray] = []
    pending_scale: np.ndarray | None = None
    for name, dtype, shape in layout:
        if name.endswith(("ln_attn", "ln_mlp", "ln_final")):
            params.append(np.zeros(shape, np.float32))
        elif dtype == "i8":
            fan_in = shape[0]
            w = rng.normal(0.0, fan_in**-0.5, size=shape).astype(np.float32)
            w_q, scales = quantize_per_channel(jnp.asarray(w))
            params.append(np.asarray(w_q))
            pending_scale = np.asarray(scales)
        elif name.split(".")[-1].startswith("s_"):
            assert pending_scale is not None, f"scale {name} without weight"
            assert pending_scale.shape == shape
            params.append(pending_scale)
            pending_scale = None
        else:
            fan_in = shape[0]
            params.append(rng.normal(0.0, fan_in**-0.5, size=shape).astype(np.float32))
    assert len(params) == len(layout)
    return params


def _unpack(cfg: ModelConfig, params: list[jax.Array]):
    """Flat list -> (embed, per-layer dicts, ln_final)."""
    layout = cfg.param_layout()
    assert len(params) == len(layout), f"{len(params)} vs {len(layout)}"
    by_name = {name: p for (name, _, _), p in zip(layout, params)}
    layers = []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        layers.append({k: by_name[p + k] for k in (
            "ln_attn", "wq", "wk", "wv", "wo",
            "ln_mlp", "w_gate_q", "s_gate", "w_up_q", "s_up", "w_down_q", "s_down",
        )})
    return by_name["embed"], layers, by_name["ln_final"]


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------

def _rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [..., T, H, D], positions broadcastable to [..., T]."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)  # [half]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., T, half]
    cos = jnp.cos(ang)[..., None, :]  # [..., T, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _mlp(cfg: ModelConfig, lp: dict, x2d: jax.Array) -> jax.Array:
    """SwiGLU MLP over flattened rows via the quantized-GEMM kernel."""
    gate = quant_matmul(x2d, lp["w_gate_q"], lp["s_gate"])
    up = quant_matmul(x2d, lp["w_up_q"], lp["s_up"])
    act = jax.nn.silu(gate) * up
    return quant_matmul(act, lp["w_down_q"], lp["s_down"])


# --------------------------------------------------------------------------
# Prefill
# --------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params: list[jax.Array], tokens: jax.Array, lens: jax.Array):
    """Process a padded prompt batch; build the KV cache.

    tokens: i32[B, S] right-padded; lens: i32[B] true lengths (>= 1).
    Returns (last_logits f32[B, V], kv_k, kv_v f32[L, B, Smax, Hkv, Dh]).
    """
    embed, layers, ln_final = _unpack(cfg, params)
    b, s = tokens.shape
    smax = cfg.max_seq
    scale = cfg.head_dim**-0.5

    x = embed[tokens] * jnp.sqrt(jnp.float32(cfg.d_model))  # [B,S,D]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    kv_k = jnp.zeros((cfg.n_layers, b, smax, cfg.n_kv_heads, cfg.head_dim), jnp.float32)
    kv_v = jnp.zeros_like(kv_k)

    causal = jnp.tril(jnp.ones((s, s), bool))[None, None]  # [1,1,S,S]

    for li, lp in enumerate(layers):
        h = rmsnorm(x, lp["ln_attn"])
        q = (h @ lp["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
        k = (h @ lp["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ lp["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        q = _rope(q, positions, cfg.rope_theta)
        k = _rope(k, positions, cfg.rope_theta)

        kv_k = kv_k.at[li, :, :s].set(k)
        kv_v = kv_v.at[li, :, :s].set(v)

        # Plain-jnp causal GQA attention (prefill is one-shot, not the hot
        # path); expand kv heads to query heads.
        group = cfg.n_heads // cfg.n_kv_heads
        k_e = jnp.repeat(k, group, axis=2)
        v_e = jnp.repeat(v, group, axis=2)
        att = jnp.einsum("bthd,bshd->bhts", q, k_e) * scale
        att = jnp.where(causal, att, -1e30)
        p = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhts,bshd->bthd", p, v_e).reshape(b, s, cfg.q_dim)
        x = x + o @ lp["wo"]

        h2 = rmsnorm(x, lp["ln_mlp"])
        x = x + _mlp(cfg, lp, h2.reshape(b * s, cfg.d_model)).reshape(b, s, cfg.d_model)

    x = rmsnorm(x, ln_final)
    last = jnp.take_along_axis(x, (lens - 1)[:, None, None], axis=1)[:, 0]  # [B,D]
    logits = last @ embed.T  # tied embeddings
    return logits, kv_k, kv_v


# --------------------------------------------------------------------------
# Decode step
# --------------------------------------------------------------------------

def decode_step(cfg: ModelConfig, params: list[jax.Array], token: jax.Array,
                pos: jax.Array, kv_k: jax.Array, kv_v: jax.Array):
    """One token per row: write kv at pos[b], attend over [0, pos[b]].

    token: i32[B], pos: i32[B] (cache index of this token, == current
    sequence length before the step). Returns (logits[B,V], kv_k', kv_v').
    """
    embed, layers, ln_final = _unpack(cfg, params)
    b = token.shape[0]
    scale = cfg.head_dim**-0.5

    x = embed[token] * jnp.sqrt(jnp.float32(cfg.d_model))  # [B,D]
    lens = pos + 1  # attend over [0, pos] inclusive

    def write_row(cache_row, val_row, p):
        # cache_row [Smax, Hkv, Dh], val_row [1, Hkv, Dh]
        return jax.lax.dynamic_update_slice(cache_row, val_row, (p, 0, 0))

    for li, lp in enumerate(layers):
        h = rmsnorm(x, lp["ln_attn"])
        q = (h @ lp["wq"]).reshape(b, cfg.n_heads, cfg.head_dim)
        k = (h @ lp["wk"]).reshape(b, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ lp["wv"]).reshape(b, cfg.n_kv_heads, cfg.head_dim)
        q = _rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        k = _rope(k[:, None], pos[:, None], cfg.rope_theta)[:, 0]

        kv_k = kv_k.at[li].set(jax.vmap(write_row)(kv_k[li], k[:, None], pos))
        kv_v = kv_v.at[li].set(jax.vmap(write_row)(kv_v[li], v[:, None], pos))

        o = decode_attention(q, kv_k[li], kv_v[li], lens, scale=scale)  # [B,H,Dh]
        x = x + o.reshape(b, cfg.q_dim) @ lp["wo"]

        h2 = rmsnorm(x, lp["ln_mlp"])
        x = x + _mlp(cfg, lp, h2)

    x = rmsnorm(x, ln_final)
    logits = x @ embed.T
    return logits, kv_k, kv_v


# --------------------------------------------------------------------------
# Chunked decode (§Perf L2): K greedy steps inside one executable
# --------------------------------------------------------------------------

def decode_chunk(cfg: ModelConfig, params: list[jax.Array], token: jax.Array,
                 pos: jax.Array, kv_k: jax.Array, kv_v: jax.Array, steps: int):
    """Run `steps` greedy decode iterations in-graph (lax.scan).

    Greedy sampling (argmax) is deterministic, so the whole
    token -> logits -> argmax -> token recurrence can live inside the
    compiled graph. One host<->device KV round-trip then amortizes over
    `steps` tokens instead of one — the dominant request-path cost
    through the PJRT literal interface (EXPERIMENTS.md §Perf).

    token: i32[B] (the chunk's first input token, already *emitted*);
    pos: i32[B] its cache slot. Returns (tokens i32[steps, B], kv_k',
    kv_v', next_token i32[B], next_pos i32[B]) where tokens[k] is the
    token generated AFTER consuming the k-th input. Rows that emit EOS
    keep generating (garbage the Rust session truncates); positions
    advance uniformly so the cache layout stays rectangular.
    """
    def step(carry, _):
        cur, p, kk, kvv = carry
        logits, kk, kvv = decode_step(cfg, params, cur, p, kk, kvv)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        # clamp so in-graph steps never write past the cache even when
        # the Rust session asks for a full chunk near max_seq
        p_next = jnp.minimum(p + 1, cfg.max_seq - 1)
        return (nxt, p_next, kk, kvv), nxt

    (next_token, next_pos, kv_k, kv_v), toks = jax.lax.scan(
        step, (token, pos, kv_k, kv_v), None, length=steps
    )
    return toks, kv_k, kv_v, next_token, next_pos


# --------------------------------------------------------------------------
# Reference generation loop (used by tests; rust reimplements this loop)
# --------------------------------------------------------------------------

def generate_greedy(cfg: ModelConfig, params, tokens: np.ndarray, lens: np.ndarray,
                    max_new: int, eos_id: int = 0) -> list[list[int]]:
    """Greedy decode loop mirroring rust/src/runtime/session.rs."""
    pj = [jnp.asarray(p) for p in params]
    logits, kv_k, kv_v = prefill(cfg, pj, jnp.asarray(tokens, jnp.int32),
                                 jnp.asarray(lens, jnp.int32))
    b = tokens.shape[0]
    out: list[list[int]] = [[] for _ in range(b)]
    done = np.zeros(b, bool)
    pos = np.asarray(lens, np.int32).copy()
    cur = np.asarray(jnp.argmax(logits, -1), np.int32)
    for _ in range(max_new):
        for i in range(b):
            if not done[i]:
                out[i].append(int(cur[i]))
                if cur[i] == eos_id:
                    done[i] = True
        if done.all() or int(pos.max()) >= cfg.max_seq:
            break
        logits, kv_k, kv_v = decode_step(cfg, pj, jnp.asarray(cur), jnp.asarray(pos),
                                         kv_k, kv_v)
        pos = pos + 1
        cur = np.asarray(jnp.argmax(logits, -1), np.int32)
    return out
