"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written
with plain jax.numpy ops. pytest (python/tests/) asserts allclose between
kernel and oracle across hypothesis-generated shapes/dtypes; this file is
the single source of truth for kernel semantics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quant_matmul_ref(x: jax.Array, w_q: jax.Array, scales: jax.Array) -> jax.Array:
    """int8-weight x f32-activation matmul with per-output-channel dequant.

    x:      f32[M, K]   activations
    w_q:    i8 [K, N]   quantized weights
    scales: f32[N]      per-output-channel dequantization scales
    returns f32[M, N] = (x @ w_q) * scales  (dequant after accumulation,
    which is exact because scales factor out of the K-sum)
    """
    acc = jnp.dot(x, w_q.astype(jnp.float32), preferred_element_type=jnp.float32)
    return acc * scales[None, :]


def rmsnorm_ref(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Gemma-style RMSNorm: x * rsqrt(mean(x^2) + eps) * (1 + weight).

    x: f32[..., D], weight: f32[D].
    """
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    normed = x * jax.lax.rsqrt(var + eps)
    return normed * (1.0 + weight)


def decode_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lens: jax.Array,
    scale: float | None = None,
) -> jax.Array:
    """Single-step (decode) GQA attention over a padded KV cache.

    q:    f32[B, H, D]        one query vector per (batch, head)
    k:    f32[B, S, Hkv, D]   padded key cache (junk beyond lens[b])
    v:    f32[B, S, Hkv, D]   padded value cache
    lens: i32[B]              valid cache length per row (attend to < lens[b])
    returns f32[B, H, D]

    H must be a multiple of Hkv (grouped-query attention: query head h
    reads kv head h // (H // Hkv)).
    """
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    assert H % Hkv == 0, "GQA requires H % Hkv == 0"
    group = H // Hkv
    if scale is None:
        scale = 1.0 / (D**0.5)

    # Expand kv heads to query heads: [B, S, H, D]
    k_e = jnp.repeat(k, group, axis=2)
    v_e = jnp.repeat(v, group, axis=2)

    # scores [B, H, S]
    s = jnp.einsum("bhd,bshd->bhs", q, k_e) * scale
    mask = jnp.arange(S)[None, None, :] < lens[:, None, None]
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, v_e)
