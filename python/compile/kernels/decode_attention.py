"""Pallas kernel: flash-decode GQA attention over the padded KV cache.

The serving hot path: one query token per sequence attends over its KV
cache. The paper's hardware does this with CUDA warp-per-row reductions;
the TPU re-think (DESIGN.md §Hardware-Adaptation):

- grid is (B, H): one program instance per (sequence, query-head), the
  natural decode parallelism (no sequence-level parallelism to exploit —
  there is exactly one query position);
- the kv-head block for that instance is selected in the BlockSpec index
  map (``h // group``), so GQA sharing is expressed as HBM->VMEM block
  routing rather than an explicit gather;
- inside the kernel an **online-softmax** loop walks the cache in
  ``CHUNK``-sized slices (``pl.ds``), carrying the running max ``m``,
  normalizer ``l`` and weighted accumulator — the flash-decode recurrence —
  so the VMEM working set is one chunk of K and V, not the whole cache;
- per-row valid lengths mask out cache padding (positions >= lens[b]).

``interpret=True`` per the image constraint; block/chunk choices drive the
§Perf VMEM analysis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Cache positions processed per online-softmax step. 64 keeps the chunk
# working set (2 * CHUNK * D f32) comfortably inside VMEM for D <= 256
# while amortizing loop overhead over the small edge-model caches.
CHUNK = 64

_NEG_INF = -1e30


def _decode_attn_kernel(q_ref, k_ref, v_ref, lens_ref, o_ref, *, scale: float, chunk: int):
    """One (b, h) instance: online-softmax attention of a single query.

    Block views:
      q_ref:    (1, 1, D)       this row+head's query
      k_ref:    (1, S, 1, D)    this row's kv-head key cache (S padded)
      v_ref:    (1, S, 1, D)
      lens_ref: (1,)            valid cache length for this row
      o_ref:    (1, 1, D)
    """
    d = q_ref.shape[-1]
    s_padded = k_ref.shape[1]
    n_chunks = s_padded // chunk

    q = q_ref[0, 0, :] * scale  # [D]
    length = lens_ref[0]

    def body(c, carry):
        m_prev, l_prev, acc_prev = carry
        start = c * chunk
        k_blk = k_ref[0, pl.ds(start, chunk), 0, :]  # [chunk, D]
        v_blk = v_ref[0, pl.ds(start, chunk), 0, :]  # [chunk, D]

        s = k_blk @ q  # [chunk]
        idx = start + jax.lax.iota(jnp.int32, chunk)
        s = jnp.where(idx < length, s, _NEG_INF)

        m_new = jnp.maximum(m_prev, jnp.max(s))
        p = jnp.exp(s - m_new)  # [chunk]
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p)
        acc_new = acc_prev * alpha + p @ v_blk  # [D]
        return m_new, l_new, acc_new

    m0 = jnp.float32(_NEG_INF)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((d,), jnp.float32)
    m_f, l_f, acc_f = jax.lax.fori_loop(0, n_chunks, body, (m0, l0, acc0))

    # l_f == 0 can only happen for an all-masked cache (length == 0, which
    # the wrapper forbids); guard anyway so padding rows emit zeros.
    o_ref[0, 0, :] = acc_f / jnp.maximum(l_f, 1e-30)


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("scale", "chunk"))
def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lens: jax.Array,
    *,
    scale: float | None = None,
    chunk: int = CHUNK,
) -> jax.Array:
    """Flash-decode attention. Semantics == ref.decode_attention_ref.

    q: f32[B, H, D], k/v: f32[B, S, Hkv, D], lens: i32[B] -> f32[B, H, D].
    The cache length S is zero-padded up to a multiple of ``chunk``; padded
    positions are masked by the lens comparison.
    """
    b, h, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    if k.shape != (b, s, hkv, d) or v.shape != k.shape:
        raise ValueError(f"bad kv shapes: q{q.shape} k{k.shape} v{v.shape}")
    if h % hkv != 0:
        raise ValueError(f"GQA requires H % Hkv == 0, got H={h} Hkv={hkv}")
    if lens.shape != (b,):
        raise ValueError(f"lens must be [B]; got {lens.shape}")
    group = h // hkv
    if scale is None:
        scale = 1.0 / float(d) ** 0.5

    ch = min(chunk, _ceil_to(s, 8))
    sp = _ceil_to(s, ch)
    kp = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    vp = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, sp - s), (0, 0), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_decode_attn_kernel, scale=float(scale), chunk=ch),
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda bb, hh: (bb, hh, 0)),
            pl.BlockSpec((1, sp, 1, d), lambda bb, hh: (bb, 0, hh // group, 0)),
            pl.BlockSpec((1, sp, 1, d), lambda bb, hh: (bb, 0, hh // group, 0)),
            pl.BlockSpec((1,), lambda bb, hh: (bb,)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bb, hh: (bb, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), jnp.float32),
        interpret=True,
    )(q.astype(jnp.float32), kp, vp, lens.astype(jnp.int32))
    return out
