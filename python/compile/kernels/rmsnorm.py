"""Pallas kernel: fused Gemma-style RMSNorm.

Bandwidth-bound op: the naive jnp version (square -> mean -> rsqrt -> two
multiplies) costs several HBM round-trips; fusing it keeps the (block_rows,
D) tile resident in VMEM for the whole normalize-and-scale sequence. Grid
is 1-D over row blocks; D stays whole inside the block (edge-model D <= 4k
easily fits VMEM — see DESIGN.md §Perf for the footprint table).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(var + eps) * (1.0 + w_ref[...])[None, :]


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("block_rows", "eps"))
def rmsnorm(
    x: jax.Array,
    weight: jax.Array,
    *,
    block_rows: int = 128,
    eps: float = 1e-6,
) -> jax.Array:
    """f32[..., D] RMSNorm with (1 + weight) scaling, Gemma convention.

    Leading dims are flattened to rows; rows are zero-padded to the block
    grid (padded rows normalize garbage-free zeros and are sliced away).
    """
    if weight.ndim != 1 or x.shape[-1] != weight.shape[0]:
        raise ValueError(f"weight[D] must match x[..., D]; got {x.shape} vs {weight.shape}")
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d).astype(jnp.float32)

    br = min(block_rows, _ceil_to(max(rows, 1), 8))
    rp = _ceil_to(max(rows, 1), br)
    xp = jnp.pad(x2, ((0, rp - rows), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rp // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, d), jnp.float32),
        interpret=True,
    )(xp, weight.astype(jnp.float32))
    return out[:rows].reshape(orig_shape)
