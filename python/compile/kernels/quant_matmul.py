"""Pallas kernel: int8-weight x f32-activation matmul with per-channel dequant.

This is the QAT-GEMM hot spot of the paper's quantized Gemma checkpoints,
re-thought for the TPU execution model (see DESIGN.md §Hardware-Adaptation):

- instead of a CUDA threadblock dequantizing int8 tiles into shared memory
  and issuing tensor-core WMMA, we tile the GEMM with ``BlockSpec``s so the
  (bm, bk) activation tile and (bk, bn) int8 weight tile stream HBM->VMEM,
  dequantize on the VPU, and accumulate on the MXU in f32;
- the K grid dimension is innermost so the f32 accumulator lives in the
  revisited output block across K steps (the canonical Pallas matmul
  accumulation pattern) — no HBM round-trip for partial sums;
- per-output-channel scales are applied once after the final K step, which
  is exact because the scale factors out of the K-reduction.

``interpret=True`` is mandatory on this image (CPU PJRT cannot run Mosaic
custom-calls); the block structure is still what a real TPU lowering would
use, and DESIGN.md §Perf derives the VMEM footprint / MXU utilisation
estimates from these block shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qmm_kernel(x_ref, wq_ref, scale_ref, o_ref):
    """One (m, n, k) grid step: o[m, n] (+)= x[m, k] @ dequant(wq[k, n])."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    w = wq_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k_step == pl.num_programs(2) - 1)
    def _dequant():
        o_ref[...] *= scale_ref[...][None, :]


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def quant_matmul(
    x: jax.Array,
    w_q: jax.Array,
    scales: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """f32[M,K] x i8[K,N] (+ f32[N] scales) -> f32[M,N].

    Shapes need not be multiples of the block sizes: inputs are zero-padded
    up to the block grid (zero K-padding contributes nothing to the
    accumulation) and the result is sliced back to [M, N].
    """
    if x.ndim != 2 or w_q.ndim != 2 or scales.ndim != 1:
        raise ValueError(
            f"quant_matmul expects x[M,K], w_q[K,N], scales[N]; got "
            f"{x.shape}, {w_q.shape}, {scales.shape}"
        )
    m, k = x.shape
    k2, n = w_q.shape
    if k != k2 or scales.shape[0] != n:
        raise ValueError(
            f"inconsistent shapes: x[{m},{k}] w_q[{k2},{n}] scales[{scales.shape[0]}]"
        )

    bm = min(block_m, _ceil_to(m, 8))
    bn = min(block_n, _ceil_to(n, 8))
    bk = min(block_k, _ceil_to(k, 8))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)

    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w_q.astype(jnp.int8), ((0, kp - k), (0, np_ - n)))
    sp = jnp.pad(scales.astype(jnp.float32), (0, np_ - n))

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _qmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, sp)
    return out[:m, :n]


def quantize_per_channel(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-output-channel int8 quantization of f32[K, N].

    Returns (w_q i8[K,N], scales f32[N]) such that w ~= w_q * scales.
    Columns that are entirely zero get scale 0 (and all-zero codes).
    """
    absmax = jnp.max(jnp.abs(w), axis=0)
    scales = absmax / 127.0
    safe = jnp.where(scales > 0, scales, 1.0)
    w_q = jnp.clip(jnp.round(w / safe[None, :]), -127, 127).astype(jnp.int8)
    return w_q, scales.astype(jnp.float32)
