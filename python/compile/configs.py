"""Model variant configurations for the edge cluster.

The paper serves Gemma-3-1B-it-qat (Jetson Orin NX 8GB) and
Gemma-3-12B-it-qat (Ada 2000 16GB) via Ollama. We cannot ship real Gemma
weights, so each variant here is a Gemma-*architecture* miniature
(RMSNorm + RoPE + GQA + SwiGLU + tied embeddings + int8-quantized MLP)
with deterministic seeded weights. The Rust coordinator serves these for
real through PJRT; the calibrated device simulator supplies
Jetson/Ada-scale timing and energy (DESIGN.md §Real-vs-calibrated-clock).

Shared serving geometry (must match rust/src/runtime/):
  PREFILL_LEN  — prompts are tokenized/truncated/padded to this length
  MAX_SEQ      — KV-cache capacity (PREFILL_LEN + max new tokens)
  BATCH_SIZES  — the paper's batch configurations {1, 4, 8}
"""

from __future__ import annotations

import dataclasses

PREFILL_LEN = 64
MAX_SEQ = 192
BATCH_SIZES = (1, 4, 8)
# greedy decode steps fused into one executable (§Perf L2 optimization)
DECODE_CHUNK = 8
VOCAB = 256  # byte-level vocabulary; tokenizer must agree (rust workload::tokenizer)
EOS_ID = 0
MANIFEST_VERSION = 2


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Gemma-style decoder-only transformer geometry."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    rope_theta: float = 10_000.0
    max_seq: int = MAX_SEQ
    seed: int = 0

    def __post_init__(self) -> None:
        if self.n_heads % self.n_kv_heads != 0:
            raise ValueError("n_heads must be a multiple of n_kv_heads (GQA)")
        if self.head_dim % 2 != 0:
            raise ValueError("head_dim must be even (RoPE pairs)")

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_layout(self) -> list[tuple[str, str, tuple[int, ...]]]:
        """Flat (name, dtype, shape) list — THE param order contract.

        The Rust runtime feeds execute() literals in exactly this order,
        followed by the activations. aot.py serializes weights.bin in this
        order too. Keep all three in sync.
        """
        c = self
        layout: list[tuple[str, str, tuple[int, ...]]] = [
            ("embed", "f32", (c.vocab, c.d_model)),
        ]
        for i in range(c.n_layers):
            p = f"layer{i}."
            layout += [
                (p + "ln_attn", "f32", (c.d_model,)),
                (p + "wq", "f32", (c.d_model, c.q_dim)),
                (p + "wk", "f32", (c.d_model, c.kv_dim)),
                (p + "wv", "f32", (c.d_model, c.kv_dim)),
                (p + "wo", "f32", (c.q_dim, c.d_model)),
                (p + "ln_mlp", "f32", (c.d_model,)),
                (p + "w_gate_q", "i8", (c.d_model, c.d_ff)),
                (p + "s_gate", "f32", (c.d_ff,)),
                (p + "w_up_q", "i8", (c.d_model, c.d_ff)),
                (p + "s_up", "f32", (c.d_ff,)),
                (p + "w_down_q", "i8", (c.d_ff, c.d_model)),
                (p + "s_down", "f32", (c.d_model,)),
            ]
        layout.append(("ln_final", "f32", (c.d_model,)))
        return layout

    def param_count(self) -> int:
        return sum(
            int.__mul__(1, 1) * _prod(shape) for _, _, shape in self.param_layout()
        )


def _prod(shape: tuple[int, ...]) -> int:
    out = 1
    for s in shape:
        out *= s
    return out


# The two edge variants, mirroring the paper's Gemma-3-1B / Gemma-3-12B
# capacity gap (~4.3x parameters here vs ~12x in the paper; the simulator's
# per-device token rates carry the real performance gap).
EDGE_1B_SIM = ModelConfig(
    name="edge-1b-sim",
    vocab=VOCAB,
    d_model=128,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    seed=101,
)

EDGE_12B_SIM = ModelConfig(
    name="edge-12b-sim",
    vocab=VOCAB,
    d_model=256,
    n_layers=4,
    n_heads=8,
    n_kv_heads=4,
    head_dim=32,
    d_ff=512,
    seed=102,
)

VARIANTS: dict[str, ModelConfig] = {
    c.name: c for c in (EDGE_1B_SIM, EDGE_12B_SIM)
}
