"""AOT path integrity: manifest, weight sidecars, HLO text well-formedness.

Lowers a throwaway tiny variant into a tmpdir (fast), so these tests do
not depend on `make artifacts` having run.
"""

import json
import pathlib

import numpy as np
import pytest

from compile import aot, configs, model

TINY = configs.ModelConfig(
    name="tiny-aot", vocab=32, d_model=16, n_layers=1, n_heads=2,
    n_kv_heads=1, head_dim=8, d_ff=16, max_seq=24, seed=3,
)


@pytest.fixture(scope="module")
def lowered(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.lower_variant(TINY, out, batch_sizes=(1, 2), prefill_len=8)
    return out, manifest


class TestLowerVariant:
    def test_entries_exist(self, lowered):
        out, man = lowered
        assert set(man["entries"]) == {
            "prefill_b1", "prefill_b2", "decode_b1", "decode_b2",
            "decode_chunk_b1", "decode_chunk_b2",
        }
        for e in man["entries"].values():
            p = out / e["file"]
            assert p.exists() and p.stat().st_size > 0
        assert man["entries"]["decode_chunk_b1"]["steps"] == configs.DECODE_CHUNK

    def test_hlo_is_text_with_entry(self, lowered):
        out, man = lowered
        for e in man["entries"].values():
            text = (out / e["file"]).read_text()
            assert "HloModule" in text.splitlines()[0]
            assert "ENTRY" in text
            # serialized protos would not be valid UTF-8 text; also assert
            # no stablehlo leaked through (must be classic HLO)
            assert "stablehlo" not in text

    def test_weight_sidecar_roundtrip(self, lowered):
        out, man = lowered
        blob = (out / man["weights_file"]).read_bytes()
        assert len(blob) == man["weights_bytes"]
        params = model.init_params(TINY)
        for meta, arr in zip(man["params"], params):
            lo, hi = meta["offset"], meta["offset"] + meta["bytes"]
            got = np.frombuffer(blob[lo:hi], dtype=aot.DTYPE_NP[meta["dtype"]])
            np.testing.assert_array_equal(got, np.ascontiguousarray(arr).ravel())

    def test_param_meta_matches_layout(self, lowered):
        _, man = lowered
        layout = TINY.param_layout()
        assert [m["name"] for m in man["params"]] == [n for n, _, _ in layout]
        assert [tuple(m["shape"]) for m in man["params"]] == [s for _, _, s in layout]
        # offsets contiguous
        off = 0
        for m in man["params"]:
            assert m["offset"] == off
            off += m["bytes"]

    @staticmethod
    def _entry_param_count(text: str) -> int:
        import re
        entry = text[text.index("ENTRY"):]
        ids = {int(m) for m in re.findall(r"parameter\((\d+)\)", entry)}
        assert ids == set(range(len(ids))), "non-contiguous parameter ids"
        return len(ids)

    def test_hlo_parameter_count(self, lowered):
        """HLO entry must take n_params + activation args."""
        out, man = lowered
        n = len(TINY.param_layout())
        text = (out / man["entries"]["prefill_b1"]["file"]).read_text()
        assert self._entry_param_count(text) == n + 2  # + tokens, lens
        text = (out / man["entries"]["decode_b1"]["file"]).read_text()
        assert self._entry_param_count(text) == n + 4  # + token, pos, kv_k, kv_v

    def test_deterministic_weights_sha(self, lowered, tmp_path):
        _, man = lowered
        man2 = aot.lower_variant(TINY, tmp_path, batch_sizes=(1,), prefill_len=8)
        assert man["weights_sha256"] == man2["weights_sha256"]


class TestShippedManifest:
    """Checks against the real artifacts/ if `make artifacts` has run."""

    ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"

    @pytest.fixture()
    def manifest(self):
        p = self.ART / "manifest.json"
        if not p.exists():
            pytest.skip("artifacts not built (run `make artifacts`)")
        return json.loads(p.read_text())

    def test_versions_and_geometry(self, manifest):
        assert manifest["version"] == configs.MANIFEST_VERSION
        assert manifest["prefill_len"] == configs.PREFILL_LEN
        assert manifest["max_seq"] == configs.MAX_SEQ
        assert manifest["vocab"] == configs.VOCAB
        assert set(manifest["batch_sizes"]) == set(configs.BATCH_SIZES)

    def test_all_variants_present(self, manifest):
        assert set(manifest["variants"]) == set(configs.VARIANTS)
        for name, v in manifest["variants"].items():
            cfg = configs.VARIANTS[name]
            for b in configs.BATCH_SIZES:
                assert f"prefill_b{b}" in v["entries"]
                assert f"decode_b{b}" in v["entries"]
            assert (self.ART / v["weights_file"]).stat().st_size == v["weights_bytes"]
            assert len(v["params"]) == len(cfg.param_layout())
