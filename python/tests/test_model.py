"""L2 model semantics: prefill/decode consistency, padding, determinism."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import configs, model

TINY = configs.ModelConfig(
    name="tiny-test", vocab=32, d_model=16, n_layers=2, n_heads=4,
    n_kv_heads=2, head_dim=4, d_ff=24, max_seq=24, seed=7,
)


@pytest.fixture(scope="module")
def tiny_params():
    return [jnp.asarray(p) for p in model.init_params(TINY)]


def _toks(rows, cfg=TINY, s=8):
    """Right-padded token batch + lens from a list of python lists."""
    b = len(rows)
    t = np.zeros((b, s), np.int32)
    lens = np.zeros((b,), np.int32)
    for i, r in enumerate(rows):
        t[i, : len(r)] = r
        lens[i] = len(r)
    return jnp.asarray(t), jnp.asarray(lens)


class TestParams:
    def test_layout_matches_init(self):
        layout = TINY.param_layout()
        params = model.init_params(TINY)
        assert len(layout) == len(params)
        for (name, dt, shape), p in zip(layout, params):
            assert p.shape == tuple(shape), name
            assert p.dtype == (np.int8 if dt == "i8" else np.float32), name

    def test_init_deterministic(self):
        a = model.init_params(TINY)
        b = model.init_params(TINY)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    def test_different_seed_different_weights(self):
        import dataclasses
        other = dataclasses.replace(TINY, seed=8)
        a, b = model.init_params(TINY), model.init_params(other)
        assert any(not np.array_equal(x, y) for x, y in zip(a, b))

    def test_gqa_validation(self):
        with pytest.raises(ValueError):
            configs.ModelConfig(name="bad", vocab=8, d_model=8, n_layers=1,
                                n_heads=3, n_kv_heads=2, head_dim=4, d_ff=8)

    def test_variant_layouts_well_formed(self):
        for cfg in configs.VARIANTS.values():
            layout = cfg.param_layout()
            names = [n for n, _, _ in layout]
            assert len(names) == len(set(names))
            assert names[0] == "embed" and names[-1] == "ln_final"


class TestPrefill:
    def test_shapes(self, tiny_params):
        toks, lens = _toks([[1, 2, 3], [4, 5, 6, 7]])
        logits, kv_k, kv_v = model.prefill(TINY, tiny_params, toks, lens)
        assert logits.shape == (2, TINY.vocab)
        assert kv_k.shape == (TINY.n_layers, 2, TINY.max_seq,
                              TINY.n_kv_heads, TINY.head_dim)
        assert kv_v.shape == kv_k.shape
        assert np.isfinite(np.asarray(logits)).all()

    def test_padding_invariance(self, tiny_params):
        """Logits at lens-1 must not depend on pad content/extra pad."""
        toks_a, lens = _toks([[1, 2, 3]], s=8)
        toks_b = toks_a.at[0, 3:].set(31)  # different pad garbage
        la, *_ = model.prefill(TINY, tiny_params, toks_a, lens)
        lb, *_ = model.prefill(TINY, tiny_params, toks_b, lens)
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-4, atol=1e-5)

    def test_batch_row_independence(self, tiny_params):
        """Row 0's logits identical whether row 1 exists or differs."""
        t2, l2 = _toks([[1, 2, 3], [9, 9]])
        t2b, _ = _toks([[1, 2, 3], [4, 4]])
        a, *_ = model.prefill(TINY, tiny_params, t2, l2)
        b, *_ = model.prefill(TINY, tiny_params, t2b, l2)
        np.testing.assert_allclose(np.asarray(a)[0], np.asarray(b)[0],
                                   rtol=1e-4, atol=1e-5)

    def test_kv_written_only_below_prefill_len(self, tiny_params):
        toks, lens = _toks([[1, 2, 3]], s=8)
        _, kv_k, _ = model.prefill(TINY, tiny_params, toks, lens)
        tail = np.asarray(kv_k)[:, :, 8:]
        assert np.abs(tail).max() == 0.0


class TestDecodeStep:
    def test_prefill_decode_agree(self, tiny_params):
        """decode_step at position L must equal prefill over L+1 tokens.

        This is the invariant the whole serving loop rests on: incremental
        decode with the flash kernel reproduces one-shot prefill logits.
        """
        seq = [3, 7, 1, 12, 5]
        # one-shot over the full sequence
        toks_full, lens_full = _toks([seq], s=8)
        want, *_ = model.prefill(TINY, tiny_params, toks_full, lens_full)
        # prefill over the prefix, then decode the last token
        toks_pre, lens_pre = _toks([seq[:-1]], s=8)
        _, kv_k, kv_v = model.prefill(TINY, tiny_params, toks_pre, lens_pre)
        got, _, _ = model.decode_step(
            TINY, tiny_params,
            jnp.asarray([seq[-1]], jnp.int32),
            jnp.asarray([len(seq) - 1], jnp.int32),
            kv_k, kv_v,
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-3, atol=5e-4)

    def test_decode_updates_kv_at_pos(self, tiny_params):
        toks, lens = _toks([[1, 2]], s=8)
        _, kv_k, kv_v = model.prefill(TINY, tiny_params, toks, lens)
        _, kv_k2, _ = model.decode_step(
            TINY, tiny_params, jnp.asarray([5], jnp.int32),
            jnp.asarray([2], jnp.int32), kv_k, kv_v)
        before, after = np.asarray(kv_k), np.asarray(kv_k2)
        assert np.abs(after[:, 0, 2]).max() > 0.0          # written at pos 2
        np.testing.assert_array_equal(before[:, 0, :2], after[:, 0, :2])
        np.testing.assert_array_equal(before[:, 0, 3:], after[:, 0, 3:])

    def test_ragged_batch_positions(self, tiny_params):
        """Rows with different pos must write at their own cache slots only."""
        toks, lens = _toks([[1, 2, 3], [4]], s=8)
        _, kv_k, kv_v = model.prefill(TINY, tiny_params, toks, lens)
        _, kv_k2, _ = model.decode_step(
            TINY, tiny_params, jnp.asarray([9, 9], jnp.int32),
            jnp.asarray(lens), kv_k, kv_v)
        before, after = np.asarray(kv_k), np.asarray(kv_k2)
        # row 0 wrote at pos 3, row 1 at pos 1; everything else untouched
        assert not np.array_equal(before[:, 0, 3], after[:, 0, 3])
        assert not np.array_equal(before[:, 1, 1], after[:, 1, 1])
        mask = np.ones_like(before, bool)
        mask[:, 0, 3] = False
        mask[:, 1, 1] = False
        np.testing.assert_array_equal(before[mask], after[mask])


class TestDecodeChunk:
    def test_chunk_equals_repeated_steps(self, tiny_params):
        """decode_chunk(K) must replay K greedy decode_step iterations."""
        toks, lens = _toks([[1, 2, 3], [4, 5]], s=8)
        logits, kv_k, kv_v = model.prefill(TINY, tiny_params, toks, lens)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = jnp.asarray(lens)

        # reference: K single steps
        k = 5
        ref_tokens = []
        rk, rv, rcur, rpos = kv_k, kv_v, cur, pos
        for _ in range(k):
            lg, rk, rv = model.decode_step(TINY, tiny_params, rcur, rpos, rk, rv)
            rcur = jnp.argmax(lg, -1).astype(jnp.int32)
            rpos = rpos + 1
            ref_tokens.append(np.asarray(rcur))

        toks_c, ck, cv, ncur, npos = model.decode_chunk(
            TINY, tiny_params, cur, pos, kv_k, kv_v, k)
        np.testing.assert_array_equal(np.asarray(toks_c), np.stack(ref_tokens))
        np.testing.assert_array_equal(np.asarray(ncur), np.asarray(rcur))
        np.testing.assert_array_equal(np.asarray(npos), np.asarray(rpos))
        np.testing.assert_allclose(np.asarray(ck), np.asarray(rk), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(cv), np.asarray(rv), rtol=1e-5, atol=1e-6)

    def test_chunk_clamps_at_cache_end(self, tiny_params):
        """Positions freeze at max_seq-1 instead of writing out of bounds."""
        toks, lens = _toks([[1, 2]], s=8)
        _, kv_k, kv_v = model.prefill(TINY, tiny_params, toks, lens)
        pos = jnp.asarray([TINY.max_seq - 2], jnp.int32)
        _, _, _, _, npos = model.decode_chunk(
            TINY, tiny_params, jnp.asarray([3], jnp.int32), pos, kv_k, kv_v, 6)
        assert int(np.asarray(npos)[0]) == TINY.max_seq - 1


class TestGenerate:
    def test_greedy_deterministic(self, tiny_params):
        params = model.init_params(TINY)
        toks = np.zeros((2, 8), np.int32)
        toks[0, :3] = [1, 2, 3]
        toks[1, :2] = [4, 5]
        lens = np.array([3, 2], np.int32)
        a = model.generate_greedy(TINY, params, toks, lens, max_new=6)
        b = model.generate_greedy(TINY, params, toks, lens, max_new=6)
        assert a == b
        assert all(len(row) <= 6 for row in a)

    def test_eos_stops_row(self, tiny_params):
        """A row that emits EOS must stop growing (EOS id = 0)."""
        params = model.init_params(TINY)
        toks = np.zeros((1, 8), np.int32)
        toks[0, :2] = [1, 2]
        lens = np.array([2], np.int32)
        out = model.generate_greedy(TINY, params, toks, lens, max_new=10)
        row = out[0]
        if 0 in row:
            assert row.index(0) == len(row) - 1
