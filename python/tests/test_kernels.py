"""Kernel-vs-oracle correctness: the CORE L1 signal.

hypothesis sweeps shapes/dtypes; every Pallas kernel must match its
pure-jnp oracle in compile/kernels/ref.py to tight tolerances.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.decode_attention import decode_attention
from compile.kernels.quant_matmul import quant_matmul, quantize_per_channel
from compile.kernels.rmsnorm import rmsnorm

HYP = dict(max_examples=25, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


# --------------------------------------------------------------------------
# quant_matmul
# --------------------------------------------------------------------------

class TestQuantMatmul:
    @given(
        m=st.integers(1, 70), k=st.integers(1, 90), n=st.integers(1, 70),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(**HYP)
    def test_matches_ref(self, m, k, n, seed):
        rng = _rng(seed)
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
        wq, sc = quantize_per_channel(w)
        got = quant_matmul(x, wq, sc)
        want = ref.quant_matmul_ref(x, wq, sc)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @given(
        bm=st.sampled_from([8, 16, 32, 128]),
        bn=st.sampled_from([8, 16, 64, 128]),
        bk=st.sampled_from([8, 32, 128]),
    )
    @settings(**HYP)
    def test_block_shape_invariance(self, bm, bn, bk):
        """Result must not depend on the tiling choice."""
        rng = _rng(7)
        x = jnp.asarray(rng.normal(size=(33, 47)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(47, 29)), jnp.float32)
        wq, sc = quantize_per_channel(w)
        got = quant_matmul(x, wq, sc, block_m=bm, block_n=bn, block_k=bk)
        want = ref.quant_matmul_ref(x, wq, sc)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_extreme_int8_codes(self):
        """Full int8 code range including -127/127 saturation."""
        k, n = 16, 8
        wq = jnp.asarray(
            _rng(3).integers(-127, 128, size=(k, n)), jnp.int8
        )
        sc = jnp.asarray(_rng(4).uniform(1e-4, 2.0, size=(n,)), jnp.float32)
        x = jnp.asarray(_rng(5).normal(size=(5, k)), jnp.float32)
        np.testing.assert_allclose(
            quant_matmul(x, wq, sc), ref.quant_matmul_ref(x, wq, sc),
            rtol=1e-5, atol=1e-5,
        )

    def test_zero_activation_gives_zero(self):
        x = jnp.zeros((4, 12), jnp.float32)
        wq = jnp.ones((12, 6), jnp.int8)
        sc = jnp.ones((6,), jnp.float32)
        assert np.abs(np.asarray(quant_matmul(x, wq, sc))).max() == 0.0

    def test_quantize_roundtrip_error_bounded(self):
        """Dequantized weights within half an LSB of the original."""
        w = jnp.asarray(_rng(11).normal(size=(64, 32)), jnp.float32)
        wq, sc = quantize_per_channel(w)
        deq = np.asarray(wq, np.float32) * np.asarray(sc)[None, :]
        lsb = np.asarray(sc)[None, :]
        assert (np.abs(deq - np.asarray(w)) <= 0.5 * lsb + 1e-8).all()

    def test_zero_column_scale_zero(self):
        w = jnp.zeros((8, 3), jnp.float32)
        wq, sc = quantize_per_channel(w)
        assert np.asarray(sc).max() == 0.0
        assert np.abs(np.asarray(wq)).max() == 0

    def test_shape_errors(self):
        with pytest.raises(ValueError):
            quant_matmul(jnp.zeros((2, 3), jnp.float32),
                         jnp.zeros((4, 5), jnp.int8),
                         jnp.zeros((5,), jnp.float32))
        with pytest.raises(ValueError):
            quant_matmul(jnp.zeros((2, 3, 1), jnp.float32),
                         jnp.zeros((3, 5), jnp.int8),
                         jnp.zeros((5,), jnp.float32))


# --------------------------------------------------------------------------
# rmsnorm
# --------------------------------------------------------------------------

class TestRmsNorm:
    @given(
        rows=st.integers(1, 100), d=st.integers(1, 300),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(**HYP)
    def test_matches_ref_2d(self, rows, d, seed):
        rng = _rng(seed)
        x = jnp.asarray(rng.normal(size=(rows, d)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
        np.testing.assert_allclose(
            rmsnorm(x, w), ref.rmsnorm_ref(x, w), rtol=1e-5, atol=1e-5
        )

    @given(
        b=st.integers(1, 4), s=st.integers(1, 16), d=st.sampled_from([8, 64, 128]),
    )
    @settings(**HYP)
    def test_matches_ref_3d(self, b, s, d):
        rng = _rng(b * 1000 + s * 10 + d)
        x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
        np.testing.assert_allclose(
            rmsnorm(x, w), ref.rmsnorm_ref(x, w), rtol=1e-5, atol=1e-5
        )

    def test_unit_rows_have_unit_rms(self):
        """With zero gain, output rows have RMS ~= 1 for nonzero input."""
        x = jnp.asarray(_rng(0).normal(size=(32, 64)), jnp.float32)
        out = np.asarray(rmsnorm(x, jnp.zeros((64,), jnp.float32)))
        rms = np.sqrt((out**2).mean(axis=-1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)

    def test_scale_equivariance(self):
        """rmsnorm(c*x) == rmsnorm(x) for c > 0 (scale-invariant op)."""
        x = jnp.asarray(_rng(1).normal(size=(8, 32)), jnp.float32)
        w = jnp.asarray(_rng(2).normal(size=(32,)), jnp.float32)
        a = np.asarray(rmsnorm(x, w))
        b = np.asarray(rmsnorm(x * 7.5, w))
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)

    def test_zero_input_stays_finite(self):
        out = np.asarray(rmsnorm(jnp.zeros((4, 16), jnp.float32),
                                 jnp.zeros((16,), jnp.float32)))
        assert np.isfinite(out).all() and np.abs(out).max() == 0.0

    def test_shape_error(self):
        with pytest.raises(ValueError):
            rmsnorm(jnp.zeros((4, 16), jnp.float32), jnp.zeros((8,), jnp.float32))


# --------------------------------------------------------------------------
# decode_attention
# --------------------------------------------------------------------------

class TestDecodeAttention:
    @given(
        b=st.integers(1, 5),
        hkv=st.sampled_from([1, 2, 4]),
        group=st.sampled_from([1, 2, 4]),
        d=st.sampled_from([8, 16, 32, 64]),
        s=st.integers(1, 200),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(**HYP)
    def test_matches_ref(self, b, hkv, group, d, s, seed):
        h = hkv * group
        rng = _rng(seed)
        q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
        lens = jnp.asarray(rng.integers(1, s + 1, size=(b,)), jnp.int32)
        got = decode_attention(q, k, v, lens)
        want = ref.decode_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)

    def test_padding_invariance(self):
        """Garbage beyond lens must not affect the output."""
        rng = _rng(42)
        b, h, hkv, d, s = 2, 4, 2, 16, 50
        q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
        lens = jnp.asarray([20, 37], jnp.int32)
        base = decode_attention(q, k, v, lens)
        k2 = k.at[:, 45:].set(1e6)
        v2 = v.at[:, 45:].set(-1e6)
        got = decode_attention(q, k2, v2, lens)
        np.testing.assert_allclose(base, got, rtol=1e-6)

    def test_single_position_returns_value(self):
        """lens == 1: softmax over one key returns v[:, 0] exactly."""
        rng = _rng(9)
        b, h, hkv, d, s = 3, 4, 4, 8, 16
        q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
        lens = jnp.ones((b,), jnp.int32)
        got = np.asarray(decode_attention(q, k, v, lens))
        np.testing.assert_allclose(got, np.asarray(v[:, 0]), rtol=1e-5, atol=1e-6)

    def test_chunk_invariance(self):
        """Online-softmax result independent of chunk size."""
        rng = _rng(5)
        b, h, hkv, d, s = 2, 4, 2, 16, 130
        q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
        lens = jnp.asarray([130, 64], jnp.int32)
        outs = [np.asarray(decode_attention(q, k, v, lens, chunk=c))
                for c in (8, 16, 64, 256)]
        for o in outs[1:]:
            np.testing.assert_allclose(outs[0], o, rtol=2e-5, atol=1e-6)

    def test_large_score_stability(self):
        """Online softmax must survive large score magnitudes."""
        b, h, hkv, d, s = 1, 2, 1, 8, 64
        q = jnp.full((b, h, d), 50.0, jnp.float32)
        k = jnp.full((b, s, hkv, d), 50.0, jnp.float32)
        v = jnp.asarray(_rng(3).normal(size=(b, s, hkv, d)), jnp.float32)
        lens = jnp.asarray([s], jnp.int32)
        got = np.asarray(decode_attention(q, k, v, lens))
        assert np.isfinite(got).all()
        # equal scores -> uniform average of values
        np.testing.assert_allclose(
            got[0, 0], np.asarray(v[0, :, 0]).mean(0), rtol=1e-4, atol=1e-5
        )

    def test_gqa_group_routing(self):
        """Query head h must read kv head h // group, not any other."""
        rng = _rng(6)
        b, hkv, group, d, s = 1, 2, 2, 8, 4
        h = hkv * group
        q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
        # kv head 0 values = +1, kv head 1 values = -1
        v = jnp.concatenate([
            jnp.ones((b, s, 1, d)), -jnp.ones((b, s, 1, d))
        ], axis=2).astype(jnp.float32)
        lens = jnp.asarray([s], jnp.int32)
        got = np.asarray(decode_attention(q, k, v, lens))
        np.testing.assert_allclose(got[0, :group], 1.0, rtol=1e-5)
        np.testing.assert_allclose(got[0, group:], -1.0, rtol=1e-5)

    def test_shape_errors(self):
        f32 = jnp.float32
        with pytest.raises(ValueError):  # H not multiple of Hkv
            decode_attention(jnp.zeros((1, 3, 8), f32), jnp.zeros((1, 4, 2, 8), f32),
                             jnp.zeros((1, 4, 2, 8), f32), jnp.ones((1,), jnp.int32))
        with pytest.raises(ValueError):  # lens wrong shape
            decode_attention(jnp.zeros((2, 4, 8), f32), jnp.zeros((2, 4, 2, 8), f32),
                             jnp.zeros((2, 4, 2, 8), f32), jnp.ones((3,), jnp.int32))
        with pytest.raises(ValueError):  # v mismatched
            decode_attention(jnp.zeros((1, 4, 8), f32), jnp.zeros((1, 4, 2, 8), f32),
                             jnp.zeros((1, 5, 2, 8), f32), jnp.ones((1,), jnp.int32))
